//! A small fixed-size thread pool (rayon replacement).
//!
//! Two entry points:
//!
//! - [`ThreadPool::run`] — execute a batch of independent closures and
//!   wait for all of them (panics are propagated).
//! - [`parallel_map_indexed`] — convenience for "apply f to 0..n in
//!   parallel, collect results in order", the shape of every tile batch in
//!   the native engine.
//!
//! Jobs are `'static` at the channel level; the scoped-borrow use cases go
//! through `std::thread::scope` inside `parallel_map_indexed`, so callers
//! can borrow locals freely.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed worker pool over an mpsc queue.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (`n >= 1`).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("palmad-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx, handles }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Run all jobs, blocking until every one has finished.
    pub fn run(&self, jobs: Vec<Job>) {
        let (done_tx, done_rx) = channel();
        let n = jobs.len();
        for job in jobs {
            let done = done_tx.clone();
            self.tx
                .send(Msg::Run(Box::new(move || {
                    job();
                    let _ = done.send(());
                })))
                .expect("pool send");
        }
        for _ in 0..n {
            done_rx.recv().expect("pool worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Default parallelism: available cores, capped at 16 (the tile batches
/// are memory-bandwidth-bound; more threads stop helping well before 16).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Apply `f(i)` for `i in 0..n` across `threads` scoped workers; results
/// are returned in index order.  Work is distributed by an atomic cursor
/// (dynamic scheduling — tile costs are skewed by early abandons).
pub fn parallel_map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let cursor = AtomicUsize::new(0);
    let slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY-free approach: short critical section per item.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker filled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let jobs: Vec<Job> = (0..100)
            .map(|i| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(i as u64, Ordering::Relaxed);
                }) as Job
            })
            .collect();
        pool.run(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_reusable_across_batches() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let c = Arc::clone(&counter);
            pool.run(vec![Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })]);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let got = parallel_map_indexed(1000, 8, |i| i * 2);
        assert_eq!(got, (0..1000).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_borrows_locals() {
        let data: Vec<f64> = (0..100).map(|x| x as f64).collect();
        let got = parallel_map_indexed(100, 4, |i| data[i] + 1.0);
        assert_eq!(got[99], 100.0);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map_indexed(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map_indexed(1, 4, |i| i + 7), vec![7]);
    }
}
