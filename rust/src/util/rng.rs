//! Deterministic PRNG: xoshiro256** with splitmix64 seeding.
//!
//! Every stochastic component in the repo (generators, property tests,
//! workload samplers) goes through this generator so runs are exactly
//! reproducible from a `u64` seed — a requirement for the experiment
//! harness (EXPERIMENTS.md records seeds next to results).
#![forbid(unsafe_code)]

/// xoshiro256** (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Self { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free is overkill
    /// here; modulo bias is negligible for the `n` we use, but we still
    /// reject to keep property tests honest).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n64 = n as u64;
        let zone = u64::MAX - u64::MAX % n64;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// True with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed(123);
        let mut b = Rng::seed(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed(124);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed(5);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seed(6);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(7);
        let n = 200_000;
        let mut s1 = 0.0;
        let mut s2 = 0.0;
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn int_in_inclusive() {
        let mut r = Rng::seed(8);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..1_000 {
            let v = r.int_in(3, 5);
            assert!((3..=5).contains(&v));
            hit_lo |= v == 3;
            hit_hi |= v == 5;
        }
        assert!(hit_lo && hit_hi);
    }
}
