//! Poison-recovering lock helpers.
//!
//! The job service shares its tables (`jobs`, `queue`, `uploads`,
//! `workers`) and the engine pool slots across worker threads and
//! connection handlers.  With plain `lock().unwrap()`, one panicking
//! worker poisons the mutex and every subsequent handler panics in a
//! cascade — a single bad job takes the whole service down.
//!
//! `lock_recover`/`wait_recover` instead take the guard out of the
//! `PoisonError`.  That is sound here by construction: every critical
//! section in the service is a single map/queue operation (insert,
//! remove, push, pop); multi-step mutations happen on values *removed*
//! from the tables while no lock is held (the claim/park pattern in
//! `coordinator::service::step_job`).  A panic inside a critical
//! section therefore cannot leave a table half-updated, so the
//! recovered state is consistent and the poison flag carries no
//! information we need.
//!
//! `PoisonError::into_inner` is used rather than `Mutex::clear_poison`
//! so the helpers do not depend on a newer toolchain; the flag stays
//! set, and every subsequent access goes through recovery again, which
//! is cheap.
//!
//! The primitives come through [`crate::util::loomsync`], so the
//! poison-recovery path itself is model-checked: the
//! `sync_poison_recovery_no_lost_wakeup` model in
//! `rust/tests/loom_models.rs` proves a panicking lock holder cannot
//! cost a waiter its wakeup.
#![forbid(unsafe_code)]

use crate::util::loomsync::{Condvar, Mutex, MutexGuard};

/// Lock a mutex, recovering the guard if a previous holder panicked.
#[inline]
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// `Condvar::wait` that recovers the guard on poison instead of
/// panicking.  Spurious-wakeup semantics are unchanged.
#[inline]
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// `Condvar::wait_timeout` that recovers the guard on poison instead of
/// panicking.  Returns the guard plus whether the wait timed out.
/// Callers must re-check their predicate either way — a timeout, a
/// notify, and a spurious wakeup are indistinguishable from a protocol
/// standpoint (under the loom model the wait always reports a timeout,
/// so timed waiters can never wedge a model — see `vendor/loom`).
#[inline]
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(poisoned) => {
            let (g, t) = poisoned.into_inner();
            (g, t.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(0u32));
        let m2 = Arc::clone(&m);
        // Poison the mutex: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_recover(&m);
        *g += 1;
        assert_eq!(*g, 1);
    }

    #[test]
    fn wait_timeout_recover_times_out_without_a_notifier() {
        use crate::util::loomsync::{Condvar as LCondvar, Mutex as LMutex};
        let m = LMutex::new(false);
        let cv = LCondvar::new();
        let g = super::lock_recover(&m);
        let (g, timed_out) =
            super::wait_timeout_recover(&cv, g, std::time::Duration::from_millis(5));
        assert!(timed_out, "no notifier: the wait must report a timeout");
        assert!(!*g, "predicate untouched");
    }

    #[test]
    fn wait_recover_wakes_through_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        // Poison the mutex first.
        let p3 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _g = p3.0.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let waker = std::thread::spawn(move || {
            let mut flag = lock_recover(&p2.0);
            *flag = true;
            p2.1.notify_all();
        });
        let mut g = lock_recover(&pair.0);
        while !*g {
            g = wait_recover(&pair.1, g);
        }
        waker.join().unwrap();
    }
}
