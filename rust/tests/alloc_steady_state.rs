//! Zero-allocation proofs for the hot loops.
//!
//! A counting global allocator wraps `System`; after warmup (scratch
//! arenas sized, seed cache populated, worker pool spawned, output
//! blocks grown, coordinator workspace bound) each steady-state loop
//! must perform **zero** heap allocations:
//!
//! 1. the native engine's raw tile-batch loop (PR 1) — and the explicit
//!    `TileKernel::Lanes4` variant at a tile edge off the lane grid,
//!    where the scalar tail and the lane-aligned scratch rows are hot,
//! 2. MERLIN's per-length adaptive-r retry loop over a hoisted
//!    [`MerlinWorkspace`], and
//! 3. the streaming monitor's warm `push()` loop — **including** its
//!    scheduled PD3 refreshes, which recycle the monitor's stats
//!    buffer, workspace, and the engine's spare seed rows.
//!
//! `scripts/ci.sh --kernel-matrix` re-runs this whole file under
//! `PALMAD_TILE_KERNEL=scalar` and `=lanes4` (the default-config engines
//! above follow the env), so both kernels carry the zero-allocation
//! guarantee.
//!
//! This file contains only these tests, serialized through one mutex so
//! no concurrent test pollutes the shared counter.

// The only unsafe outside the lib's allowlisted modules: the counting
// GlobalAlloc below.  Same discipline as the lib (CONCURRENCY.md).
#![deny(unsafe_op_in_unsafe_fn)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::drag::{pd3_into, Pd3Config};
use palmad::coordinator::lease::EnginePool;
use palmad::coordinator::merlin::{MerlinConfig, MerlinSweep};
use palmad::coordinator::metrics::DragMetrics;
use palmad::coordinator::streaming::{StreamConfig, StreamMonitor};
use palmad::coordinator::workspace::MerlinWorkspace;
use palmad::core::stats::RollingStats;
use palmad::engines::native::{NativeConfig, NativeEngine};
use palmad::engines::{Engine, SeriesView, TileKernel, TileTask};
use palmad::runtime::types::TileOutputs;
use palmad::util::rng::Rng;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static SERIAL: Mutex<()> = Mutex::new(());

struct CountingAllocator;

// SAFETY: defers entirely to `System`; only counts on the side.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds `GlobalAlloc::alloc`'s contract.
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds `GlobalAlloc::alloc_zeroed`'s contract.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds `GlobalAlloc::realloc`'s contract.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    // SAFETY: caller upholds `GlobalAlloc::dealloc`'s contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: same ptr/layout pairing the caller guarantees.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn random_walk(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed(seed);
    let mut acc = 0.0;
    (0..n)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect()
}

/// Run `body` until a pass of it performs zero allocations (buffers
/// ratchet to their high-water marks on early passes), failing after
/// `attempts` non-clean passes.  The claim under test is always that a
/// zero-allocation steady state is *reached and stays*.
fn assert_reaches_alloc_free_steady_state(
    what: &str,
    attempts: usize,
    mut body: impl FnMut(),
) {
    let mut last_delta = u64::MAX;
    for _ in 0..attempts {
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        body();
        last_delta = ALLOC_CALLS.load(Ordering::SeqCst) - before;
        if last_delta == 0 {
            return;
        }
    }
    panic!("{what}: still {last_delta} heap allocations per pass after {attempts} attempts");
}

#[test]
// Workload-heavy and allocation-counting, not aliasing-sensitive: the
// unsafe surface here (the counting GlobalAlloc) is exercised by every
// other test too.  Skipped under Miri, whose interpreter makes these
// multi-round engine loops take hours; `scripts/ci.sh --miri` scopes
// the Miri pass to the unsafe core instead.
#[cfg_attr(miri, ignore)]
fn steady_state_tile_loop_is_allocation_free() {
    let _serial = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let t = random_walk(4096, 99);
    let m = 64;
    let segn = 128;
    let stats = RollingStats::compute(&t, m);
    let view = SeriesView { t: &t, stats: &stats };
    // Multiple workers so the parallel (RoundPool + SliceWriter) path is
    // the one under test, and enough tasks that every worker gets items
    // during warmup (thread-local scratch arenas are per-thread).
    let engine = NativeEngine::new(NativeConfig { segn, threads: 4, ..Default::default() });
    engine.prepare_series(&view);
    // A 4x4 grid of tiles: 16 *distinct* cache keys (a duplicated key in
    // one concurrent batch would race its cache row and legitimately
    // re-seed), covering self tiles, exclusion overlaps and both scan
    // directions.  All well inside the 4033 valid windows.
    let tasks: Vec<TileTask> = (0..16)
        .map(|k| TileTask { seg_start: (k % 4) * segn, chunk_start: (k / 4) * segn })
        .collect();
    let r2 = 9.0;

    let mut out: Vec<TileOutputs> = Vec::new();
    // Warmup: spawns the pool, sizes every scratch arena and output
    // block, and fills the seed cache (first round misses, later rounds
    // hit; both paths execute).  A worker that loses every cursor race
    // during warmup would first allocate its thread-local arena *inside*
    // the measured window — that is still warmup, which the retry helper
    // absorbs.
    for _ in 0..5 {
        engine.compute_tiles_into(&view, r2, &tasks, &mut out).unwrap();
    }
    assert_reaches_alloc_free_steady_state("tile batch loop", 5, || {
        for _ in 0..10 {
            engine.compute_tiles_into(&view, r2, &tasks, &mut out).unwrap();
        }
    });

    // Sanity: the measured rounds really computed tiles (not a no-op).
    assert_eq!(out.len(), tasks.len());
    assert!(out.iter().any(|o| o.row_min.iter().any(|d| d.is_finite())));
}

#[test]
// Skipped under Miri — see the note on the first test.
#[cfg_attr(miri, ignore)]
fn lane_kernel_tile_loop_is_allocation_free_at_unaligned_edge() {
    let _serial = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    // Explicit Lanes4 kernel at a tile edge off the lane grid (66 % 4 !=
    // 0): the lane chunks, the scalar tail loop, and the LANES-aligned
    // scratch rows are all on the measured path — the satellite claim is
    // that lane alignment is a capacity rounding, not a per-tile
    // allocation.
    let t = random_walk(4096, 77);
    let m = 48;
    let segn = 66;
    let stats = RollingStats::compute(&t, m);
    let view = SeriesView { t: &t, stats: &stats };
    let engine = NativeEngine::new(NativeConfig {
        segn,
        threads: 4,
        kernel: TileKernel::Lanes4,
        ..Default::default()
    });
    engine.prepare_series(&view);
    let tasks: Vec<TileTask> = (0..16)
        .map(|k| TileTask { seg_start: (k % 4) * segn, chunk_start: 8 * segn + (k / 4) * segn })
        .collect();
    let mut out: Vec<TileOutputs> = Vec::new();
    for _ in 0..5 {
        engine.compute_tiles_into(&view, 9.0, &tasks, &mut out).unwrap();
    }
    assert_reaches_alloc_free_steady_state("lane-kernel tile loop", 5, || {
        for _ in 0..10 {
            engine.compute_tiles_into(&view, 9.0, &tasks, &mut out).unwrap();
        }
    });
    assert_eq!(out.len(), tasks.len());
    assert!(out.iter().any(|o| o.row_min.iter().any(|d| d.is_finite())));
}

#[test]
// Skipped under Miri — see the note on the first test.
#[cfg_attr(miri, ignore)]
fn seed_prefetch_and_clear_recycle_are_allocation_free() {
    let _serial = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let t = random_walk(2048, 23);
    let (m_lo, m_hi) = (32usize, 33usize);
    let segn = 64;
    let stats_lo = RollingStats::compute(&t, m_lo);
    let stats_hi = RollingStats::compute(&t, m_hi);
    let engine = NativeEngine::new(NativeConfig { segn, threads: 4, ..Default::default() });
    // Distinct keys well inside both lengths' window ranges (no prefetch
    // drop-offs, no same-batch key races).
    let tasks: Vec<TileTask> = (0..8)
        .map(|k| TileTask { seg_start: (k % 4) * segn, chunk_start: 4 * segn + (k / 4) * segn })
        .collect();
    let mut out: Vec<TileOutputs> = Vec::new();
    // One pass = the length-loop shape: tiles at m_lo (cold: misses;
    // warm: recompute into recycled rows), bulk prefetch to m_hi, tiles
    // at m_hi (pure hits from prefetched rows), then a memory-pressure
    // clear + another m_hi batch that must rebuild entirely from the
    // spare pool.  The prefetch sweep's work list, the shard maps, and
    // every seed row ratchet to their high-water marks during warmup and
    // are recycled afterwards.
    let mut pass = |engine: &NativeEngine, out: &mut Vec<TileOutputs>| {
        let view_lo = SeriesView { t: &t, stats: &stats_lo };
        engine.compute_tiles_into(&view_lo, 9.0, &tasks, out).unwrap();
        assert_eq!(engine.prefetch_length(&t, m_hi), tasks.len() as u64);
        let view_hi = SeriesView { t: &t, stats: &stats_hi };
        engine.compute_tiles_into(&view_hi, 9.0, &tasks, out).unwrap();
        engine.clear_seed_cache();
        engine.compute_tiles_into(&view_hi, 9.0, &tasks, out).unwrap();
    };
    for _ in 0..3 {
        pass(&engine, &mut out);
    }
    assert_reaches_alloc_free_steady_state("seed prefetch + clear loop", 5, || {
        pass(&engine, &mut out);
    });
    // Sanity: the passes really exercised the bulk path and the cache.
    let c = engine.perf_counters();
    assert!(c.seed_prefetched >= 4 * tasks.len() as u64, "{c:?}");
    assert!(c.prefetch_batches >= 4, "{c:?}");
    assert!(c.seed_hits > 0 && c.seed_misses > 0, "{c:?}");
}

#[test]
// Skipped under Miri — see the note on the first test.
#[cfg_attr(miri, ignore)]
fn merlin_retry_loop_is_allocation_free() {
    let _serial = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let t = random_walk(2048, 5);
    let stats = RollingStats::compute(&t, 48);
    let view = SeriesView { t: &t, stats: &stats };
    let engine = NativeEngine::new(NativeConfig { segn: 128, threads: 4, ..Default::default() });
    let mut ws = MerlinWorkspace::new();
    let mut metrics = DragMetrics::default();
    // The retry-loop shape at one length: descending thresholds, every
    // call through the same hoisted workspace.  Later (lower-r) calls
    // keep more candidates alive, so round task counts and survivor
    // counts both grow along the schedule — exactly the buffer-growth
    // pattern the arena must absorb once and then recycle.
    // Ends at r = 0.0: nothing can be killed there, so the final call
    // exercises the maximal task/survivor volume (every buffer's
    // high-water mark) on the very first pass.
    let schedule = [12.0, 9.0, 7.0, 5.5, 4.2, 3.0, 0.0];
    let mut run_schedule = |metrics: &mut DragMetrics, ws: &mut MerlinWorkspace| {
        for &r in &schedule {
            pd3_into(&engine, &view, r, &Pd3Config::default(), metrics, ws).unwrap();
        }
    };
    // Warmup: two full passes (cold caches, pool spawn, arena growth).
    run_schedule(&mut metrics, &mut ws);
    run_schedule(&mut metrics, &mut ws);
    assert_reaches_alloc_free_steady_state("MERLIN retry loop", 5, || {
        run_schedule(&mut metrics, &mut ws);
    });
    // Sanity: the r = 0 call reports every window with a finite nn, and
    // the arena was recycled rather than rebuilt.
    assert!(!ws.discords().is_empty(), "r=0.0 must leave survivors");
    let c = ws.counters();
    assert!(c.resets >= 3 * schedule.len() as u64, "2 warmup + >=1 measured passes: {c:?}");
    assert_eq!(c.grows, 1, "only the cold rebind may grow: {c:?}");
}

/// The multi-tenant claim behind the step scheduler: two jobs on
/// *different* series, interleaving sweep steps through a shared keyed
/// lease pool, reach a zero-allocation steady state.  Sticky checkouts
/// hand each tenant back the engine whose seed cache is bound to its
/// series (so no fingerprint rebinds churn rows) and the workspace it
/// warmed; the sweeps themselves recycle their stats, result, and
/// selection buffers across `rebind`s.
#[test]
// Skipped under Miri — see the note on the first test.
#[cfg_attr(miri, ignore)]
fn interleaved_lease_pool_steps_are_allocation_free() {
    let _serial = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let t_a = random_walk(1_500, 11);
    let t_b = random_walk(1_500, 12);
    let pool = EnginePool::new(
        &EngineOptions { segn: 64, threads: 2, ..Default::default() },
        2,
    )
    .unwrap();
    let cfg = MerlinConfig { min_l: 24, max_l: 30, top_k: 1, ..Default::default() };
    let mut sweep_a = MerlinSweep::new(cfg.clone(), t_a.len()).unwrap();
    let mut sweep_b = MerlinSweep::new(cfg, t_b.len()).unwrap();
    // One pass = both jobs swept to completion with strictly
    // interleaved steps, each through a fresh keyed checkout — the
    // scheduler's steady-state shape.
    let mut pass = |sa: &mut MerlinSweep, sb: &mut MerlinSweep| {
        sa.rebind(t_a.len()).unwrap();
        sb.rebind(t_b.len()).unwrap();
        while !(sa.done() && sb.done()) {
            if !sa.done() {
                let mut lease = pool.checkout(1);
                let (engine, ws) = lease.engine_and_workspace();
                sa.step(engine, &t_a, ws).unwrap();
            }
            if !sb.done() {
                let mut lease = pool.checkout(2);
                let (engine, ws) = lease.engine_and_workspace();
                sb.step(engine, &t_b, ws).unwrap();
            }
        }
    };
    // Warmup: seed caches fill, arenas and sweep buffers ratchet to
    // their high-water marks, both tenants key their pool entries.
    for _ in 0..3 {
        pass(&mut sweep_a, &mut sweep_b);
    }
    assert_reaches_alloc_free_steady_state("interleaved lease-pool sweeps", 5, || {
        pass(&mut sweep_a, &mut sweep_b);
    });
    // Sanity: both sweeps really ran and the pool stayed sticky — no
    // tenant ever had to steal the other's engine.
    assert_eq!(sweep_a.lengths().len(), 7);
    assert_eq!(sweep_b.lengths().len(), 7);
    assert!(sweep_a.lengths().iter().all(|l| !l.discords.is_empty()));
    let c = pool.counters();
    assert_eq!(c.rebinds, 0, "sticky checkouts must never steal here: {c:?}");
    assert!(c.sticky_hits >= c.leases - 2, "all but the first checkouts are sticky: {c:?}");
    // Tenant A's final pass (metrics reset on rebind) restarted at
    // min_l on a cache full of max_l rows — misses into recycled row
    // storage — and then swept on prefetched hits, one bulk batch per
    // advanced length.
    let seed = sweep_a.metrics().seed;
    assert!(seed.seed_hits > 0, "tenant A's steps must hit its warm seed cache: {seed:?}");
    assert_eq!(seed.prefetch_batches, 6, "one bulk prefetch per advanced length: {seed:?}");
}

#[test]
// Skipped under Miri — see the note on the first test.
#[cfg_attr(miri, ignore)]
fn stream_monitor_push_loop_is_allocation_free() {
    let _serial = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
    let engine = NativeEngine::new(NativeConfig { segn: 64, threads: 2, ..Default::default() });
    let mut mon = StreamMonitor::new(
        &engine,
        StreamConfig { window: 512, m: 32, refresh: 128, alert_frac: 1.0, legacy_slide: false },
    );
    let mut rng = Rng::seed(31);
    let mut acc = 0.0;
    let mut push_points = |mon: &mut StreamMonitor<'_>, count: usize| {
        for _ in 0..count {
            acc += rng.normal();
            mon.push(acc).unwrap();
        }
    };
    // Warmup: several full windows — ring wraps, PD3 refreshes (stats
    // recompute + workspace + engine seed-row recycling), alert paths.
    push_points(&mut mon, 2048);
    // Steady state: each pass covers 512 pushes spanning multiple
    // scheduled refreshes and at least one ring wrap.
    assert_reaches_alloc_free_steady_state("stream push loop", 8, || {
        push_points(&mut mon, 512);
    });
    let c = mon.ingest_counters();
    assert!(c.refreshes >= 16, "the pass schedule must include refreshes: {c:?}");
    assert_eq!(mon.window_len(), 512, "window must be full and sliding");
}
