//! Zero-allocation proof for the native tile pipeline.
//!
//! A counting global allocator wraps `System`; after warmup (scratch
//! arenas sized, seed cache populated, worker pool spawned, output
//! blocks grown) the steady-state tile loop must perform **zero** heap
//! allocations.  This file contains only this test so no concurrent
//! test can pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use palmad::core::stats::RollingStats;
use palmad::engines::native::{NativeConfig, NativeEngine};
use palmad::engines::{Engine, SeriesView, TileTask};
use palmad::runtime::types::TileOutputs;
use palmad::util::rng::Rng;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAllocator;

// SAFETY: defers entirely to `System`; only counts on the side.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn random_walk(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed(seed);
    let mut acc = 0.0;
    (0..n)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect()
}

#[test]
fn steady_state_tile_loop_is_allocation_free() {
    let t = random_walk(4096, 99);
    let m = 64;
    let segn = 128;
    let stats = RollingStats::compute(&t, m);
    let view = SeriesView { t: &t, stats: &stats };
    // Multiple workers so the parallel (RoundPool + SliceWriter) path is
    // the one under test, and enough tasks that every worker gets items
    // during warmup (thread-local scratch arenas are per-thread).
    let engine = NativeEngine::new(NativeConfig { segn, threads: 4, ..Default::default() });
    engine.prepare_series(&view);
    // A 4x4 grid of tiles: 16 *distinct* cache keys (a duplicated key in
    // one concurrent batch would race its cache row and legitimately
    // re-seed), covering self tiles, exclusion overlaps and both scan
    // directions.  All well inside the 4033 valid windows.
    let tasks: Vec<TileTask> = (0..16)
        .map(|k| TileTask { seg_start: (k % 4) * segn, chunk_start: (k / 4) * segn })
        .collect();
    let r2 = 9.0;

    let mut out: Vec<TileOutputs> = Vec::new();
    // Warmup: spawns the pool, sizes every scratch arena and output
    // block, and fills the seed cache (first round misses, later rounds
    // hit; both paths execute).  Worker scratch arenas are thread-local
    // and populated lazily, so a worker that loses every cursor race
    // during warmup would first allocate *inside* the measured window —
    // that is still warmup, not steady state.  Hence: measure, and on a
    // nonzero count warm further and re-measure; the claim under test is
    // that a zero-allocation steady state is *reached and stays*, which
    // the final attempt must prove.
    for _ in 0..5 {
        engine.compute_tiles_into(&view, r2, &tasks, &mut out).unwrap();
    }

    let mut last_delta = u64::MAX;
    for _attempt in 0..5 {
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..10 {
            engine.compute_tiles_into(&view, r2, &tasks, &mut out).unwrap();
        }
        last_delta = ALLOC_CALLS.load(Ordering::SeqCst) - before;
        if last_delta == 0 {
            break;
        }
    }
    assert_eq!(
        last_delta, 0,
        "steady-state tile loop still performed {last_delta} heap allocations \
         across 10 rounds after extended warmup"
    );

    // Sanity: the measured rounds really computed tiles (not a no-op).
    assert_eq!(out.len(), tasks.len());
    assert!(out.iter().any(|o| o.row_min.iter().any(|d| d.is_finite())));
}
