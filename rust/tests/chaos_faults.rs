//! Chaos suite: crash-safe checkpointing and worker fault isolation,
//! exercised through the deterministic fault injector
//! (`engines/fault.rs`).
//!
//! The headline properties:
//!
//! 1. Killing a sweep at ANY step boundary and resuming it — snapshot +
//!    engine seed rows into a brand-new engine — yields a final result
//!    bit-identical to the uninterrupted run (not just numerically
//!    close: the QT seed rows carried through the checkpoint replay the
//!    exact low-order rounding of the incremental cross-length
//!    recurrence).
//! 2. An injected panic fails only its own job; other tenants, the
//!    worker pool, and the metrics endpoint keep running.
//! 3. A killed-and-restarted service auto-resumes interrupted jobs from
//!    its checkpoint dir and finishes them bit-identically.
//!
//! Fault schedules are probed first (`per_step_calls`) so injections
//! land on exact, reproducible tile-batch call indices — a chaos test
//! whose fault might not fire is a green light lying.

use palmad::coordinator::checkpoint::CheckpointStore;
use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::merlin::{MerlinConfig, MerlinResult, MerlinSweep, SweepStatus};
use palmad::coordinator::service::{JobSpec, JobState, Service, ServiceConfig};
use palmad::coordinator::workspace::MerlinWorkspace;
use palmad::core::series::TimeSeries;
use palmad::engines::fault::{FaultPlan, FaultyEngine};
use palmad::engines::native::NativeEngine;
use palmad::engines::Engine;
use palmad::gen::registry;

const SEGN: usize = 64;

fn series(n: usize, seed: u64) -> TimeSeries {
    registry::dataset_prefix("ecg2", n, seed).unwrap().series
}

fn cfg(min_l: usize, max_l: usize) -> MerlinConfig {
    MerlinConfig { min_l, max_l, top_k: 2, ..Default::default() }
}

/// Drive a sweep to completion on `engine` and return the result.
fn run_sweep(engine: &dyn Engine, cfg: &MerlinConfig, t: &TimeSeries) -> MerlinResult {
    let mut sweep = MerlinSweep::new(cfg.clone(), t.len()).unwrap();
    let mut ws = MerlinWorkspace::new();
    while matches!(sweep.step(engine, &t.values, &mut ws).unwrap(), SweepStatus::Pending) {}
    sweep.finish()
}

/// Cumulative tile-batch call count after each step, on a clean faulty
/// engine.  Engines are deterministic, so a service running the same
/// job on the same geometry replays exactly these indices.
fn per_step_calls(cfg: &MerlinConfig, t: &TimeSeries) -> Vec<u64> {
    let eng = FaultyEngine::new(Box::new(NativeEngine::with_segn(SEGN)), FaultPlan::default());
    let mut sweep = MerlinSweep::new(cfg.clone(), t.len()).unwrap();
    let mut ws = MerlinWorkspace::new();
    let mut counts = Vec::new();
    loop {
        let st = sweep.step(&eng, &t.values, &mut ws).unwrap();
        counts.push(eng.calls());
        if matches!(st, SweepStatus::Done) {
            return counts;
        }
    }
}

#[track_caller]
fn assert_bit_identical(want: &MerlinResult, got: &MerlinResult, what: &str) {
    assert_eq!(want.lengths.len(), got.lengths.len(), "{what}: length count");
    for (w, g) in want.lengths.iter().zip(&got.lengths) {
        assert_eq!(w.m, g.m, "{what}: m");
        assert_eq!(w.retries, g.retries, "{what}: retries at m={}", w.m);
        assert_eq!(
            w.r_used.to_bits(),
            g.r_used.to_bits(),
            "{what}: r_used bits at m={} ({} vs {})",
            w.m,
            w.r_used,
            g.r_used
        );
        assert_eq!(w.discords.len(), g.discords.len(), "{what}: discords at m={}", w.m);
        for (dw, dg) in w.discords.iter().zip(&g.discords) {
            assert_eq!((dw.idx, dw.m), (dg.idx, dg.m), "{what}: discord site at m={}", w.m);
            assert_eq!(
                dw.nn_dist.to_bits(),
                dg.nn_dist.to_bits(),
                "{what}: nn_dist bits at m={} idx={}",
                dw.m,
                dw.idx
            );
        }
    }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("palmad-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn wait_terminal(svc: &Service, id: u64) -> JobState {
    svc.wait(id).unwrap_or_else(|| panic!("job {id} vanished"))
}

/// Property: kill at EVERY step boundary, resume into a brand-new
/// engine, and the final result is bit-identical to the uninterrupted
/// run.  Two seeds so the adaptive-r schedule walks different paths.
#[test]
fn kill_at_every_boundary_resumes_bit_identically() {
    for seed in [7u64, 99] {
        let t = series(1_500, seed);
        let cfg = cfg(16, 24);
        let want = run_sweep(&NativeEngine::with_segn(SEGN), &cfg, &t);
        let total = want.lengths.len();
        assert!(total >= 2, "property needs interior boundaries");
        for kill in 1..total {
            // Phase 1: run `kill` steps, checkpoint, drop everything —
            // engine included, as a crash would.
            let (blob, rows) = {
                let eng = NativeEngine::with_segn(SEGN);
                let mut sweep = MerlinSweep::new(cfg.clone(), t.len()).unwrap();
                let mut ws = MerlinWorkspace::new();
                for _ in 0..kill {
                    let st = sweep.step(&eng, &t.values, &mut ws).unwrap();
                    assert!(matches!(st, SweepStatus::Pending));
                }
                (sweep.snapshot(), eng.export_seed_rows(&t.values))
            };
            // Phase 2: "new process" — fresh engine, restore, re-arm
            // the seed cache, run to completion.
            let eng = NativeEngine::with_segn(SEGN);
            let mut sweep = MerlinSweep::restore(&blob).unwrap();
            assert_eq!(sweep.progress().0, kill);
            let imported = eng.import_seed_rows(&t.values, &rows);
            assert_eq!(imported as usize, rows.len(), "every exported row re-arms");
            let mut ws = MerlinWorkspace::new();
            while matches!(sweep.step(&eng, &t.values, &mut ws).unwrap(), SweepStatus::Pending) {
            }
            assert_bit_identical(&want, &sweep.finish(), &format!("seed={seed} kill={kill}"));
        }
    }
}

/// An injected panic fails exactly one job; the lone worker survives it
/// and completes the next tenant's job, and METRICS stays live.
#[test]
fn injected_panic_fails_only_that_job() {
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions {
            segn: SEGN,
            fault: Some(FaultPlan { panic_at: 1, ..Default::default() }),
            ..Default::default()
        },
        workers: 1,
        pool_capacity: 1,
        ..Default::default()
    })
    .unwrap();
    let spec = JobSpec {
        dataset: "ecg2".into(),
        n: Some(1_000),
        seed: 7,
        min_l: 16,
        max_l: 18,
        top_k: 1,
        ..Default::default()
    };
    let victim = svc.submit(spec.clone()).unwrap();
    match wait_terminal(&svc, victim) {
        JobState::Failed(msg) => assert!(msg.contains("panic"), "{msg}"),
        other => panic!("victim should fail from the injected panic, got {other:?}"),
    }
    // The same worker and the same pooled engine carry the next job to
    // completion (the panic index is one-shot and already consumed).
    let survivor = svc.submit(JobSpec { seed: 8, ..spec }).unwrap();
    assert!(matches!(wait_terminal(&svc, survivor), JobState::Done { .. }));
    let sm = svc.sched_metrics();
    assert_eq!(sm.panics, 1, "exactly one panic caught");
    let (submitted, done, failed, _) = svc.metrics();
    assert_eq!((submitted, done, failed), (2, 1, 1));
    svc.shutdown();
}

/// A transient engine error inside a step is retried with backoff and
/// the job still completes — bit-identically to a fault-free run.
#[test]
fn transient_engine_error_is_retried_to_success() {
    let t = series(1_000, 7);
    let cfg = cfg(16, 20);
    let want = run_sweep(&NativeEngine::with_segn(SEGN), &cfg, &t);
    let counts = per_step_calls(&cfg, &t);
    // Inject exactly one error, on the last tile-batch call of the
    // final step: the retry re-runs that step and sails past (the next
    // multiple is beyond the job's total call count).
    let total_calls = *counts.last().unwrap();
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions {
            segn: SEGN,
            fault: Some(FaultPlan { error_every: total_calls, ..Default::default() }),
            ..Default::default()
        },
        workers: 1,
        pool_capacity: 1,
        ..Default::default()
    })
    .unwrap();
    let id = svc.submit(JobSpec {
        dataset: "ecg2".into(),
        n: Some(1_000),
        seed: 7,
        min_l: 16,
        max_l: 20,
        top_k: 2,
        ..Default::default()
    }).unwrap();
    match wait_terminal(&svc, id) {
        JobState::Done { discords, .. } => {
            let want_d: Vec<_> =
                want.all_discords().map(|d| (d.m, d.idx, d.nn_dist.to_bits())).collect();
            let got_d: Vec<_> =
                discords.iter().map(|d| (d.m, d.idx, d.nn_dist.to_bits())).collect();
            assert_eq!(want_d, got_d, "retried job must match the fault-free run");
        }
        other => panic!("job should survive the transient fault, got {other:?}"),
    }
    let sm = svc.sched_metrics();
    assert!(sm.step_retries >= 1, "the injected fault must actually have fired");
    assert_eq!(sm.panics, 0);
    svc.shutdown();
}

/// Silent NaN contamination of one tile must not crash anything: the
/// job runs to a terminal Done (NaN ranks last in discord selection).
#[test]
fn nan_contamination_completes_without_crash() {
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions {
            segn: SEGN,
            fault: Some(FaultPlan { seed: 5, nan_at: 1, ..Default::default() }),
            ..Default::default()
        },
        workers: 1,
        pool_capacity: 1,
        ..Default::default()
    })
    .unwrap();
    let id = svc.submit(JobSpec {
        dataset: "ecg2".into(),
        n: Some(1_000),
        seed: 7,
        min_l: 16,
        max_l: 18,
        top_k: 1,
        ..Default::default()
    }).unwrap();
    match wait_terminal(&svc, id) {
        JobState::Done { discords, .. } => {
            for d in &discords {
                assert!(d.idx < 1_000, "discord site must stay in range");
            }
        }
        // Acceptable alternative: the sweep notices the corruption and
        // fails cleanly.  Either way: no panic, no hang.
        JobState::Failed(msg) => assert!(!msg.contains("panic"), "{msg}"),
        other => panic!("unexpected {other:?}"),
    }
    svc.shutdown();
}

/// Kill the service mid-job (shutdown), restart it on the same
/// checkpoint dir, and the boot journal scan auto-resumes the job to a
/// bit-identical completion.
#[test]
fn service_restart_auto_resumes_bit_identically() {
    let dir = temp_dir("restart");
    let t = series(1_500, 7);
    let cfg = cfg(16, 40);
    let want = run_sweep(&NativeEngine::with_segn(SEGN), &cfg, &t);
    let svc_cfg = || ServiceConfig {
        engine_opts: EngineOptions { segn: SEGN, ..Default::default() },
        workers: 1,
        pool_capacity: 1,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 1,
        ..Default::default()
    };
    let spec = JobSpec {
        dataset: "ecg2".into(),
        n: Some(1_500),
        seed: 7,
        min_l: 16,
        max_l: 40,
        top_k: 2,
        ..Default::default()
    };

    // ---- First incarnation: run a few steps, then die.
    let svc = Service::start_with(svc_cfg()).unwrap();
    let id = svc.submit(spec).unwrap();
    loop {
        if svc.progress(id).map(|(done, _)| done >= 2).unwrap_or(false) {
            break;
        }
        if matches!(svc.status(id), Some(JobState::Done { .. })) {
            panic!("job finished before the kill — grow the sweep range");
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    svc.shutdown();
    match svc.status(id).unwrap() {
        JobState::Failed(msg) => assert_eq!(msg, "shutdown"),
        other => panic!("job should be interrupted by shutdown, got {other:?}"),
    }
    let store = CheckpointStore::new(dir.clone()).unwrap();
    assert!(store.exists(id), "an interrupted job keeps its checkpoint");
    drop(svc);

    // ---- Second incarnation: the boot scan picks the job up by itself.
    let svc = Service::start_with(svc_cfg()).unwrap();
    match wait_terminal(&svc, id) {
        JobState::Done { discords, .. } => {
            let want_d: Vec<_> =
                want.all_discords().map(|d| (d.m, d.idx, d.nn_dist.to_bits())).collect();
            let got_d: Vec<_> =
                discords.iter().map(|d| (d.m, d.idx, d.nn_dist.to_bits())).collect();
            assert_eq!(want_d, got_d, "resumed run must be bit-identical");
        }
        other => panic!("auto-resumed job should finish, got {other:?}"),
    }
    let sm = svc.sched_metrics();
    assert_eq!(sm.resumes, 1, "boot scan resumed exactly one job");
    assert!(sm.checkpoints >= 1, "the resumed run keeps checkpointing");
    assert!(!store.exists(id), "a completed job removes its checkpoint");
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A mid-sweep panic fails the job but keeps its checkpoint; RESUME
/// re-runs it from the last durable boundary to a bit-identical Done.
#[test]
fn resume_verb_recovers_a_panicked_job() {
    let dir = temp_dir("resume-verb");
    let t = series(1_000, 7);
    let cfg = cfg(16, 24);
    let want = run_sweep(&NativeEngine::with_segn(SEGN), &cfg, &t);
    let counts = per_step_calls(&cfg, &t);
    assert!(counts.len() >= 4, "panic must land mid-sweep");
    // Panic on the first tile-batch call of step 4: steps 1-3 have
    // checkpointed (every=1), so the resume replays from boundary 3.
    let panic_at = counts[2] + 1;
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions {
            segn: SEGN,
            fault: Some(FaultPlan { panic_at, ..Default::default() }),
            ..Default::default()
        },
        workers: 1,
        pool_capacity: 1,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 1,
        ..Default::default()
    })
    .unwrap();
    let id = svc.submit(JobSpec {
        dataset: "ecg2".into(),
        n: Some(1_000),
        seed: 7,
        min_l: 16,
        max_l: 24,
        top_k: 2,
        ..Default::default()
    }).unwrap();
    match wait_terminal(&svc, id) {
        JobState::Failed(msg) => assert!(msg.contains("panic"), "{msg}"),
        other => panic!("the injected panic should fail the job, got {other:?}"),
    }
    let resumed = svc.resume(id).unwrap();
    assert_eq!(resumed, id, "RESUME keeps the job id");
    match wait_terminal(&svc, id) {
        JobState::Done { discords, .. } => {
            let want_d: Vec<_> =
                want.all_discords().map(|d| (d.m, d.idx, d.nn_dist.to_bits())).collect();
            let got_d: Vec<_> =
                discords.iter().map(|d| (d.m, d.idx, d.nn_dist.to_bits())).collect();
            assert_eq!(want_d, got_d, "post-panic resume must be bit-identical");
        }
        other => panic!("resumed job should finish, got {other:?}"),
    }
    let sm = svc.sched_metrics();
    assert_eq!(sm.panics, 1);
    assert_eq!(sm.resumes, 1);
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Protocol-level upload hygiene: oversized and malformed DATA are
/// rejected with ERR, the connection stays in sync afterwards, and
/// RESUME without checkpointing reports a clean error.
#[test]
fn tcp_rejects_bad_uploads_and_stays_in_sync() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::Arc;

    let svc = Arc::new(
        Service::start_with(ServiceConfig {
            workers: 1,
            max_upload_points: 8,
            ..Default::default()
        })
        .unwrap(),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let svc2 = Arc::clone(&svc);
    let server = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if svc2.handle_conn_public(stream.unwrap()) {
                svc2.shutdown();
                break;
            }
        }
    });
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    let mut ask = |conn: &mut TcpStream, req: &str, line: &mut String| {
        writeln!(conn, "{req}").unwrap();
        line.clear();
        reader.read_line(line).unwrap();
    };

    // Oversized: rejected, values drained, connection still usable.
    ask(&mut conn, "DATA name=big n=9\n1 2 3 4 5 6 7 8 9", &mut line);
    assert!(line.starts_with("ERR") && line.contains("out of range"), "{line}");
    // Malformed value: rejected after consuming the batch.
    ask(&mut conn, "DATA name=bad n=4\n1 2 oops 4", &mut line);
    assert!(line.starts_with("ERR") && line.contains("bad value"), "{line}");
    // Zero points: rejected up front.
    ask(&mut conn, "DATA name=zero n=0", &mut line);
    assert!(line.starts_with("ERR"), "{line}");
    // RESUME without a checkpoint dir: clean error, not a hang.
    ask(&mut conn, "RESUME 1", &mut line);
    assert!(line.starts_with("ERR") && line.contains("not enabled"), "{line}");
    // The connection never desynchronized: a good upload still lands.
    ask(&mut conn, "DATA name=ok n=4\n1 2 3 4", &mut line);
    assert_eq!(line.trim(), "OK DATA ok n=4");
    assert_eq!(svc.upload_count(), 1, "only the well-formed upload landed");
    // Metrics advertise the robustness gauges.
    ask(&mut conn, "METRICS", &mut line);
    assert!(line.contains("faults(retries/panics)=0/0"), "{line}");
    assert!(line.contains("ckpt(saved/resumed)=0/0"), "{line}");
    ask(&mut conn, "SHUTDOWN", &mut line);
    assert_eq!(line.trim(), "OK BYE");
    server.join().unwrap();
}
