//! PR 9 serving-path coverage: the evented front end (admission
//! control, connection scalability), weighted-fair scheduling under a
//! heavy-tail tenant mix, cross-tenant tile batching, and regression
//! pins for the three service-path races fixed here:
//!
//! - submit racing shutdown stranded a QUEUED job no worker would ever
//!   pop (`submits_racing_shutdown_never_strand_a_job`);
//! - a queued job's deadline only fired once a worker dequeued it, so
//!   a saturated service reported `QUEUED` forever
//!   (`deadline_expiry_surfaces_from_status_without_a_worker`);
//! - TTL eviction only ran piggybacked on submissions, so terminal
//!   jobs — and their kept-on-Failed checkpoints — outlived their TTL
//!   indefinitely on a quiescent service
//!   (`quiescent_ttl_eviction_runs_on_the_heartbeat`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::frontend;
use palmad::coordinator::queue::SchedPolicy;
use palmad::coordinator::service::{JobSpec, JobState, Service, ServiceConfig};

fn small_spec(seed: u64) -> JobSpec {
    JobSpec {
        dataset: "ecg2".into(),
        n: Some(1_000),
        seed,
        min_l: 16,
        max_l: 19,
        top_k: 1,
        ..Default::default()
    }
}

/// A job whose *single step* runs long enough (full matrix profile of a
/// 20k-point series per length) to pin a worker for the duration of a
/// test's assertion window.
fn blocker_spec() -> JobSpec {
    JobSpec {
        dataset: "koski_ecg".into(),
        n: Some(20_000),
        seed: 1,
        min_l: 128,
        max_l: 512,
        top_k: 1,
        ..Default::default()
    }
}

fn start_reactor(
    svc: &Arc<Service>,
) -> (std::net::SocketAddr, std::thread::JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let svc = Arc::clone(svc);
    let handle = std::thread::spawn(move || frontend::serve_listener(&svc, listener));
    (addr, handle)
}

struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let conn = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        Self { conn, reader }
    }

    fn read_line(&mut self) -> String {
        let mut line = String::new();
        assert!(self.reader.read_line(&mut line).unwrap() > 0, "server closed connection");
        line.trim().to_string()
    }

    fn send(&mut self, req: &str) -> String {
        writeln!(self.conn, "{req}").unwrap();
        self.read_line()
    }
}

// ---------------------------------------------------------------------
// Bugfix (a): submit vs shutdown
// ---------------------------------------------------------------------

/// After shutdown, a late submit must come back terminal
/// (`Failed("shutdown")`), never stranded QUEUED.
#[test]
fn submit_after_shutdown_fails_with_shutdown() {
    let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
    svc.shutdown();
    let id = svc.submit(small_spec(1)).unwrap();
    match svc.status(id) {
        Some(JobState::Failed(msg)) => {
            assert!(msg.contains("shutdown"), "wrong failure: {msg:?}")
        }
        other => panic!("late submit must self-fail, got {other:?}"),
    }
}

/// Hammer submit from several threads while shutdown lands.  Every
/// accepted id must end terminal and every tenant queue must drain —
/// before PR 9 an enqueue racing the queue-clear left jobs QUEUED with
/// every worker already joined.  (The schedule-exhaustive version of
/// this pin is the `service_submit_vs_shutdown` loom model.)
#[test]
fn submits_racing_shutdown_never_strand_a_job() {
    for round in 0..8 {
        let svc = Arc::new(
            Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap(),
        );
        let submitters: Vec<_> = (0..3)
            .map(|t| {
                let svc = Arc::clone(&svc);
                std::thread::spawn(move || {
                    (0..16)
                        .map(|k| svc.submit(small_spec(round * 100 + t * 20 + k)).unwrap())
                        .collect::<Vec<u64>>()
                })
            })
            .collect();
        // Land the shutdown mid-hammer.
        std::thread::sleep(Duration::from_millis(round));
        svc.shutdown();
        for s in submitters {
            for id in s.join().unwrap() {
                let state = svc.status(id).expect("accepted job stays queryable");
                assert!(
                    state.is_some_terminal(),
                    "job {id} stranded non-terminal after shutdown: {state:?}"
                );
            }
        }
        for share in svc.tenant_shares() {
            assert_eq!(share.queued, 0, "tenant {} queue not drained", share.name);
        }
    }
}

trait TerminalExt {
    fn is_some_terminal(&self) -> bool;
}
impl TerminalExt for JobState {
    fn is_some_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

// ---------------------------------------------------------------------
// Bugfix (b): deadline expiry without a worker dequeue
// ---------------------------------------------------------------------

/// With the only worker pinned mid-step by a long job, a queued job
/// whose deadline lapses must still report `Failed("deadline
/// exceeded")` from `status()` — before PR 9, deadlines were only
/// checked when a worker dequeued the job, so this returned QUEUED.
#[test]
fn deadline_expiry_surfaces_from_status_without_a_worker() {
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions { segn: 64, ..Default::default() },
        workers: 1,
        // Keep the heartbeat out of this test: status() itself must do
        // the reaping even if the housekeeper never fires.
        housekeep_interval: Duration::from_secs(3_600),
        ..Default::default()
    })
    .unwrap();
    let blocker = svc.submit(blocker_spec()).unwrap();
    // Wait until the worker has actually dequeued the blocker, then
    // give it a beat to be inside the step.
    while !matches!(svc.status(blocker), Some(JobState::Running)) {
        std::thread::sleep(Duration::from_millis(1));
    }
    std::thread::sleep(Duration::from_millis(5));

    let victim = svc
        .submit(JobSpec { deadline: Some(Duration::from_millis(1)), ..small_spec(2) })
        .unwrap();
    std::thread::sleep(Duration::from_millis(10));
    match svc.status(victim) {
        Some(JobState::Failed(msg)) => {
            assert!(msg.contains("deadline"), "wrong failure: {msg:?}")
        }
        other => panic!("expired queued job must fail from status(), got {other:?}"),
    }
    // wait() goes through the same reap and must agree.
    assert!(
        matches!(svc.wait(victim), Some(JobState::Failed(_))),
        "wait() must surface the same terminal state"
    );
    svc.cancel(blocker).unwrap();
    svc.shutdown();
}

// ---------------------------------------------------------------------
// Bugfix (c): TTL eviction on a quiescent service
// ---------------------------------------------------------------------

/// TTL eviction (and kept-on-Failed checkpoint removal) must happen
/// with ZERO client traffic after the job fails — the housekeeper
/// heartbeat drives it.  Before PR 9, `evict_expired` only ran
/// piggybacked on the next submission.
#[test]
fn quiescent_ttl_eviction_runs_on_the_heartbeat() {
    let dir = std::env::temp_dir()
        .join(format!("palmad-hk-evict-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions { segn: 64, ..Default::default() },
        workers: 1,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 1,
        job_ttl: Duration::from_millis(300),
        housekeep_interval: Duration::from_millis(25),
        ..Default::default()
    })
    .unwrap();
    // A job that checkpoints a few lengths and then blows its deadline:
    // Failed jobs keep their checkpoint (resumable after a fix), so the
    // TTL sweep owns its removal.
    let id = svc
        .submit(JobSpec {
            dataset: "ecg2".into(),
            n: Some(4_000),
            seed: 3,
            min_l: 16,
            max_l: 200,
            top_k: 1,
            deadline: Some(Duration::from_millis(150)),
            ..Default::default()
        })
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match svc.status(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("deadline"), "{msg:?}");
                break;
            }
            Some(_) => {
                assert!(Instant::now() < deadline, "job never hit its deadline");
                std::thread::sleep(Duration::from_millis(5));
            }
            None => panic!("job evicted before its TTL"),
        }
    }
    let ckpt = dir.join(format!("job-{id}.ckpt"));
    assert!(ckpt.is_file(), "failed job must keep its checkpoint until TTL eviction");

    // Quiescence: no submits, no status polls — just the heartbeat.
    std::thread::sleep(Duration::from_millis(600));
    assert!(svc.status(id).is_none(), "TTL must evict with zero traffic");
    assert!(!ckpt.is_file(), "eviction must remove the kept-on-Failed checkpoint");
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Admission control over the wire
// ---------------------------------------------------------------------

/// Submissions over `max_queued` and connections over `max_conns` both
/// answer `ERR BUSY retry_after=<ms>`, and both are counted in
/// `rejected`.
#[test]
fn err_busy_round_trips_over_tcp() {
    let svc = Arc::new(
        Service::start_with(ServiceConfig {
            engine_opts: EngineOptions { segn: 64, ..Default::default() },
            workers: 1,
            max_queued: 1,
            max_conns: 2,
            retry_after: Duration::from_millis(75),
            ..Default::default()
        })
        .unwrap(),
    );
    let (addr, reactor) = start_reactor(&svc);
    let mut c = Client::connect(addr);

    // Pin the worker, then overfill the queue.
    let resp = c.send(
        "RUN gen=koski_ecg n=20000 minl=128 maxl=512 topk=1 seed=1",
    );
    assert!(resp.starts_with("OK JOB "), "{resp}");
    let mut accepted = 0;
    let mut busy = 0;
    for k in 0..8 {
        let resp = c.send(&format!("RUN gen=ecg2 n=1000 minl=16 maxl=19 topk=1 seed={k}"));
        if resp.starts_with("ERR BUSY") {
            assert!(
                resp.contains("retry_after=75"),
                "BUSY must carry the configured retry hint: {resp}"
            );
            busy += 1;
        } else {
            assert!(resp.starts_with("OK JOB "), "{resp}");
            accepted += 1;
        }
    }
    assert!(busy > 0, "8 submissions over max_queued=1 must trip ERR BUSY");
    assert!(accepted > 0, "admission must not reject everything");

    // Connection cap: the third concurrent connection is turned away
    // with a BUSY line and a close.
    let _second = Client::connect(addr);
    // Rejection happens on the reactor's next accept scan; read until
    // EOF and collect whatever it sent.
    let mut third = TcpStream::connect(addr).unwrap();
    let mut turned_away = String::new();
    third.read_to_string(&mut turned_away).unwrap();
    assert!(
        turned_away.starts_with("ERR BUSY retry_after=75"),
        "over-limit connection must be told to back off: {turned_away:?}"
    );

    assert!(svc.sched_metrics().rejected >= busy + 1, "rejections must be counted");
    let bye = c.send("SHUTDOWN");
    assert_eq!(bye, "OK BYE");
    reactor.join().unwrap().unwrap();
}

// ---------------------------------------------------------------------
// Connection scalability
// ---------------------------------------------------------------------

/// N idle connections must not cost N threads: the reactor multiplexes
/// them all.  The PR-5 front end spawned one thread per connection, so
/// this pinned 32 extra threads.
#[cfg(target_os = "linux")]
#[test]
fn idle_connections_share_one_thread() {
    fn thread_count() -> usize {
        let status = std::fs::read_to_string("/proc/self/status").unwrap();
        status
            .lines()
            .find_map(|l| l.strip_prefix("Threads:"))
            .and_then(|v| v.trim().parse().ok())
            .unwrap()
    }
    let svc = Arc::new(
        Service::start_with(ServiceConfig {
            engine_opts: EngineOptions { segn: 64, ..Default::default() },
            workers: 1,
            ..Default::default()
        })
        .unwrap(),
    );
    let (addr, reactor) = start_reactor(&svc);
    // One round-trip so the reactor is demonstrably up.
    let mut probe = Client::connect(addr);
    assert!(probe.send("METRICS").starts_with("OK "));

    let before = thread_count();
    let idle: Vec<Client> = (0..32).map(|_| Client::connect(addr)).collect();
    // Prove they are all live connections (accepted, not backlogged),
    // then let them idle.
    std::thread::sleep(Duration::from_millis(50));
    assert!(svc.open_conns() >= 33, "reactor must have accepted the idle fleet");
    let after = thread_count();
    // Margin of 8 absorbs unrelated tests' worker threads starting in
    // parallel; the per-connection-thread design this guards against
    // would add 32.
    assert!(
        after <= before + 8,
        "idle connections must not add threads (before {before}, after {after})"
    );
    drop(idle);
    let bye = probe.send("SHUTDOWN");
    assert_eq!(bye, "OK BYE");
    reactor.join().unwrap().unwrap();
}

// ---------------------------------------------------------------------
// Weighted fairness + batching
// ---------------------------------------------------------------------

/// Heavy-tail mix: one tenant floods 12 jobs at weight 1; a weight-8
/// tenant submits 3.  Under DRR the paid tenant's jobs finish while
/// the flood has barely started; under the flat PR-5 queue they'd sit
/// behind ~a full round-robin of the flood (~all of it done first).
#[test]
fn weighted_fairness_under_heavy_tail_mix() {
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions { segn: 64, ..Default::default() },
        workers: 2,
        sched_policy: SchedPolicy::WeightedFair,
        batch_max: 1, // isolate DRR ordering from ride-along batching
        ..Default::default()
    })
    .unwrap();
    let flood: Vec<u64> = (0..12)
        .map(|k| {
            svc.submit(JobSpec {
                tenant: "flood".into(),
                weight: 1,
                min_l: 16,
                max_l: 31,
                ..small_spec(k)
            })
            .unwrap()
        })
        .collect();
    let paid: Vec<u64> = (0..3)
        .map(|k| {
            svc.submit(JobSpec {
                tenant: "paid".into(),
                weight: 8,
                min_l: 16,
                max_l: 31,
                ..small_spec(100 + k)
            })
            .unwrap()
        })
        .collect();
    for &id in &paid {
        assert!(
            matches!(svc.wait(id), Some(JobState::Done { .. })),
            "paid job {id} must complete"
        );
    }
    // The moment the paid tenant drains, the flood must still be mostly
    // pending — weight 8 vs 1 means the flood got at most ~1/8th of the
    // steps while both were runnable.  (Flat FIFO finishes most of the
    // flood first; this asserts the weights actually shaped order.)
    let flood_done = flood
        .iter()
        .filter(|&&id| matches!(svc.status(id), Some(JobState::Done { .. })))
        .count();
    assert!(
        flood_done <= 4,
        "flood tenant finished {flood_done}/12 jobs before the weight-8 tenant drained — \
         weights are not shaping the schedule"
    );
    let m = svc.sched_metrics();
    assert!(m.budget_exhausted > 0, "DRR budgets never rotated");
    // Steps attributed per tenant must be visible for operators.
    let shares = svc.tenant_shares();
    let paid_share = shares.iter().find(|s| s.name == "paid").expect("paid registered");
    assert_eq!(paid_share.weight, 8);
    assert_eq!(paid_share.steps, 3 * 16, "16 lengths per paid job, 3 jobs");
    for &id in &flood {
        svc.wait(id);
    }
    svc.shutdown();
}

/// Small jobs from different tenants share one engine lease round when
/// batching is on.
#[test]
fn small_jobs_batch_across_tenants_on_one_lease() {
    let svc = Service::start_with(ServiceConfig {
        engine_opts: EngineOptions { segn: 64, ..Default::default() },
        workers: 1,
        sched_policy: SchedPolicy::WeightedFair,
        batch_max: 4,
        batch_small_points: 100_000,
        ..Default::default()
    })
    .unwrap();
    let ids: Vec<u64> = (0..6)
        .map(|k| {
            svc.submit(JobSpec {
                tenant: format!("t{}", k % 3),
                ..small_spec(k)
            })
            .unwrap()
        })
        .collect();
    for id in ids {
        assert!(matches!(svc.wait(id), Some(JobState::Done { .. })));
    }
    assert!(
        svc.sched_metrics().batched_rounds > 0,
        "six small jobs over three tenants on one worker must batch at least once"
    );
    svc.shutdown();
}
