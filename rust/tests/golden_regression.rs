//! Golden regression fixtures for the end-to-end discovery paths.
//!
//! Each fixture runs a small deterministic workload (`Merlin::run`, the
//! stream monitor, `distributed_drag`) under **both** tile kernels,
//! renders the result as stable text lines (indices, bit-level
//! distances), and:
//!
//! 1. asserts the scalar and lane kernels produce identical lines;
//! 2. asserts the fixture's analytic envelope (mirrors of assertions
//!    that have been green in the unit suites since PR 2/3, plus — for
//!    the distributed fixture — a full brute-force oracle);
//! 3. diffs the lines against the checked-in golden file.
//!
//! Golden files live in `rust/tests/goldens/*.golden`.  A file whose
//! payload is the single word `unblessed` has not had exact values
//! stamped yet (the PR that introduced this harness was developed in a
//! container without a rust toolchain); the test then stops after the
//! envelope and identity checks.  On any machine with a toolchain:
//!
//! ```bash
//! PALMAD_BLESS=1 cargo test --test golden_regression
//! ```
//!
//! rewrites the files with exact output, after which every future run
//! diffs strictly — kernel changes are then compared against known-good
//! output instead of only brute-force oracles.  Everything in the lines
//! is deterministic: the PRNG is seeded, tile scheduling is
//! order-independent (pinned by `prop_thread_determinism`), and both
//! kernels are bit-identical.

use std::path::PathBuf;

use palmad::baselines::brute;
use palmad::coordinator::distributed::{distributed_drag, ExchangeMode};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::coordinator::streaming::{StreamConfig, StreamMonitor};
use palmad::core::series::TimeSeries;
use palmad::engines::native::{NativeConfig, NativeEngine};
use palmad::engines::TileKernel;
use palmad::util::rng::Rng;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/goldens")
        .join(format!("{name}.golden"))
}

/// Payload lines of a committed golden, `None` while unblessed.
fn load_golden(name: &str) -> Option<Vec<String>> {
    let path = golden_path(name);
    let raw = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden {path:?} must be committed: {e}"));
    let lines: Vec<String> = raw
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    if lines == ["unblessed"] {
        None
    } else {
        Some(lines)
    }
}

/// Compare against (or, under `PALMAD_BLESS=1`, rewrite) the golden.
fn check_golden(name: &str, lines: &[String]) {
    if std::env::var("PALMAD_BLESS").ok().as_deref() == Some("1") {
        let mut out = format!(
            "# Golden output for fixture `{name}` (rust/tests/golden_regression.rs).\n\
             # Regenerate: PALMAD_BLESS=1 cargo test --test golden_regression\n"
        );
        for l in lines {
            out.push_str(l);
            out.push('\n');
        }
        std::fs::write(golden_path(name), out).unwrap();
        eprintln!("golden {name}: blessed {} lines", lines.len());
        return;
    }
    match load_golden(name) {
        None => eprintln!(
            "golden {name}: unblessed — envelope + kernel-identity checks only \
             (stamp exact values with PALMAD_BLESS=1 on a toolchain machine)"
        ),
        Some(want) => {
            assert_eq!(
                lines.len(),
                want.len(),
                "golden {name}: line count drifted ({} vs {})",
                lines.len(),
                want.len()
            );
            for (k, (g, w)) in lines.iter().zip(&want).enumerate() {
                assert_eq!(g, w, "golden {name}: line {k} drifted");
            }
        }
    }
}

/// Distances rendered human-readable *and* bit-exact.
fn fmt_dist(d: f64) -> String {
    format!("{d:.9}/{:016x}", d.to_bits())
}

fn walk(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed(seed);
    let mut acc = 0.0;
    (0..n)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect()
}

fn engine(segn: usize, kernel: TileKernel) -> NativeEngine {
    NativeEngine::new(NativeConfig { segn, kernel, ..Default::default() })
}

const KERNELS: [TileKernel; 2] = [TileKernel::Scalar, TileKernel::Lanes4];

/// Run `fixture` under both kernels, assert identical lines, return them.
fn lines_under_both_kernels(
    name: &str,
    fixture: impl Fn(TileKernel) -> Vec<String>,
) -> Vec<String> {
    let scalar = fixture(KERNELS[0]);
    let lanes = fixture(KERNELS[1]);
    assert_eq!(scalar, lanes, "fixture {name}: kernels disagree");
    lanes
}

/// Arbitrary-length discovery over a seeded walk — the workload of the
/// long-green `finds_discords_for_every_length` unit test, with its
/// envelope, plus exact per-length output lines.
#[test]
fn golden_merlin_run() {
    let t = TimeSeries::new("rw", walk(600, 21));
    let cfg = MerlinConfig { min_l: 16, max_l: 32, top_k: 1, ..Default::default() };
    let lines = lines_under_both_kernels("merlin_walk", |kernel| {
        let e = engine(64, kernel);
        let res = Merlin::new(&e, cfg.clone()).run(&t).unwrap();
        // Envelope (mirrors the unit test that has been green since PR 1).
        assert_eq!(res.lengths.len(), 17);
        let mut out = Vec::new();
        for lr in &res.lengths {
            assert_eq!(lr.discords.len(), 1, "m={}", lr.m);
            let d = &lr.discords[0];
            assert!(d.nn_dist.is_finite() && d.nn_dist > 0.0, "m={}", lr.m);
            assert!(d.nn_dist >= lr.r_used - 1e-9, "m={}", lr.m);
            out.push(format!(
                "m={} idx={} nn={} r_used={} retries={}",
                lr.m,
                d.idx,
                fmt_dist(d.nn_dist),
                fmt_dist(lr.r_used),
                lr.retries
            ));
        }
        out
    });
    check_golden("merlin_walk", &lines);
}

/// Streaming monitor over a periodic signal with an injected burst —
/// the workload of the long-green
/// `alerts_on_injected_anomaly_between_refreshes` unit test.
#[test]
fn golden_stream_monitor() {
    let lines = lines_under_both_kernels("stream_burst", |kernel| {
        let e = engine(64, kernel);
        let mut mon = StreamMonitor::new(
            &e,
            StreamConfig {
                window: 1_024,
                m: 32,
                refresh: 128,
                alert_frac: 1.0,
                legacy_slide: false,
            },
        );
        let mut rng = Rng::seed(72);
        let mut out = Vec::new();
        let mut burst_alert = false;
        for i in 0..2_000usize {
            let mut x = (i as f64 * 0.2).sin() + 0.05 * rng.normal();
            if (1_500..1_532).contains(&i) {
                x += if i % 2 == 0 { 2.0 } else { -2.0 };
            }
            if let Some(a) = mon.push(x).unwrap() {
                // Envelope: alert coordinates are global and name the
                // subsequence completed by this push.
                assert_eq!(a.global_idx, i + 1 - 32, "alert at push {i}");
                burst_alert |= (1_500..1_600).contains(&i);
                out.push(format!(
                    "alert push={i} idx={} nn={}",
                    a.global_idx,
                    fmt_dist(a.nn_dist)
                ));
            }
        }
        assert!(burst_alert, "no alert near the injected burst");
        let c = mon.ingest_counters();
        match mon.current_discord() {
            Some(d) => out.push(format!(
                "state refreshes={} dist_evals={} discord idx={} nn={}",
                c.refreshes,
                c.dist_evals,
                d.idx,
                fmt_dist(d.nn_dist)
            )),
            None => out.push(format!(
                "state refreshes={} dist_evals={} discord=none",
                c.refreshes, c.dist_evals
            )),
        }
        out
    });
    check_golden("stream_burst", &lines);
}

/// Distributed DRAG on a seeded walk, both exchange modes and two
/// partition counts.  The envelope here is a *complete* oracle — the
/// brute-force range-discord set — so this fixture is fully verified
/// even before blessing.
#[test]
fn golden_distributed_drag() {
    let t = walk(300, 61);
    let (m, r) = (14usize, 3.5f64);
    let mut want = brute::range_discords(&t, m, r);
    want.sort_by_key(|d| d.idx);
    let lines = lines_under_both_kernels("distributed_walk", |kernel| {
        let e = engine(24, kernel);
        let mut out = Vec::new();
        for mode in [ExchangeMode::Yankov, ExchangeMode::LocalRefine] {
            for parts in [1usize, 3] {
                let (got, metrics) = distributed_drag(&e, &t, m, r, parts, mode).unwrap();
                // Envelope: exact index agreement with brute force,
                // distances within the cross-form tolerance.
                assert_eq!(
                    got.iter().map(|d| d.idx).collect::<Vec<_>>(),
                    want.iter().map(|d| d.idx).collect::<Vec<_>>(),
                    "mode={mode:?} parts={parts}"
                );
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g.nn_dist - w.nn_dist).abs() < 1e-6 * (1.0 + w.nn_dist),
                        "mode={mode:?} parts={parts} idx={}",
                        g.idx
                    );
                }
                out.push(format!(
                    "mode={mode:?} parts={parts} local={} exchanged={} survivors={}",
                    metrics.local_candidates, metrics.exchanged, metrics.survivors
                ));
                for d in &got {
                    // No indentation: the golden loader trims lines, so
                    // payload lines must round-trip whitespace-free.
                    out.push(format!("d idx={} nn={}", d.idx, fmt_dist(d.nn_dist)));
                }
            }
        }
        out
    });
    check_golden("distributed_walk", &lines);
}
