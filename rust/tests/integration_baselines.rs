//! Cross-algorithm integration: every baseline must agree with the brute
//! oracle (and with PALMAD) on what the discords are — the precondition
//! for the Fig. 4/5 comparisons to be meaningful.

use palmad::baselines::{brute, hotsax, kbf, stomp, zhu};
use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::core::series::TimeSeries;
use palmad::engines::native::NativeEngine;
use palmad::gen::ecg;
use palmad::gen::random_walk::random_walk;

fn top1_all_algorithms(t: &[f64], m: usize) -> Vec<(&'static str, f64)> {
    let brute = brute::top_k_discords(t, m, 1)[0];
    let hotsax = hotsax::top1_discord(t, m, &hotsax::HotsaxConfig::default()).unwrap();
    let zhu = zhu::zhu_top1(t, m, 4).unwrap();
    let stomp = stomp::top_k_discords(t, m, 1, 4)[0];
    let kbf = kbf::kbf_top1(t, m, 1, 4).unwrap();
    let series = TimeSeries::new("t", t.to_vec());
    let engine = NativeEngine::with_segn(64);
    let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 1, ..Default::default() };
    let palmad = Merlin::new(&engine, cfg).run(&series).unwrap().lengths[0].discords[0];
    vec![
        ("brute", brute.nn_dist),
        ("hotsax", hotsax.nn_dist),
        ("zhu", zhu.nn_dist),
        ("stomp", stomp.nn_dist),
        ("kbf(k=1)", kbf.nn_dist),
        ("palmad", palmad.nn_dist),
    ]
}

#[test]
fn all_algorithms_agree_on_random_walk() {
    let t = random_walk(1_500, 41);
    let results = top1_all_algorithms(&t.values, 32);
    let reference = results[0].1;
    for (name, d) in &results {
        assert!(
            (d - reference).abs() < 1e-5 * (1.0 + reference),
            "{name}: {d} vs brute {reference}"
        );
    }
}

#[test]
fn all_algorithms_agree_on_ecg() {
    let t = ecg::ecg_with_pvc(4_000, 128.0, 70.0, &[12], 43);
    let results = top1_all_algorithms(&t.values, 100);
    let reference = results[0].1;
    for (name, d) in &results {
        assert!(
            (d - reference).abs() < 1e-5 * (1.0 + reference),
            "{name}: {d} vs brute {reference}"
        );
    }
}

#[test]
fn stomp_profile_equals_pd3_with_r_zero() {
    // PD3 at r=0 computes the exact matrix profile (nothing prunes).
    use palmad::coordinator::drag::{pd3, Pd3Config};
    use palmad::coordinator::metrics::DragMetrics;
    use palmad::core::stats::RollingStats;
    use palmad::engines::SeriesView;

    let t = random_walk(800, 45);
    let m = 20;
    let mp = stomp::matrix_profile(&t.values, m, 4);
    let stats = RollingStats::compute(&t.values, m);
    let view = SeriesView { t: &t.values, stats: &stats };
    let engine = NativeEngine::with_segn(64);
    let mut metrics = DragMetrics::default();
    let all = pd3(&engine, &view, 0.0, &Pd3Config::default(), &mut metrics).unwrap();
    assert_eq!(all.len(), mp.len());
    for d in &all {
        let want = mp[d.idx].max(0.0).sqrt();
        assert!(
            (d.nn_dist - want).abs() < 1e-6 * (1.0 + want),
            "idx {}: {} vs {}",
            d.idx,
            d.nn_dist,
            want
        );
    }
}

#[test]
fn hotsax_and_merlin_rank_same_top3() {
    let t = random_walk(1_200, 47);
    let m = 24;
    let hs = hotsax::top_k_discords(&t.values, m, 3, &hotsax::HotsaxConfig::default());
    let series = TimeSeries::new("t", t.values.clone());
    let engine = NativeEngine::with_segn(64);
    let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 3, ..Default::default() };
    let pm = Merlin::new(&engine, cfg).run(&series).unwrap().lengths[0].discords.clone();
    assert_eq!(hs.len(), pm.len());
    for (a, b) in hs.iter().zip(&pm) {
        assert!(
            (a.nn_dist - b.nn_dist).abs() < 1e-5 * (1.0 + a.nn_dist),
            "hotsax {} vs palmad {}",
            a.nn_dist,
            b.nn_dist
        );
    }
}

#[test]
fn kbf_k3_differs_from_k1_on_twins() {
    // Sanity of the K-distance concept on the twin-freak construction.
    let mut t: Vec<f64> = (0..800).map(|i| (i as f64 * 0.15).sin()).collect();
    for off in [200usize, 600] {
        for k in 0..24 {
            t[off + k] += if k % 2 == 0 { 1.5 } else { -1.5 };
        }
    }
    let k1 = kbf::kbf_top1(&t, 24, 1, 4).unwrap();
    let k3 = kbf::kbf_top1(&t, 24, 3, 4).unwrap();
    let planted = |i: usize| (177..=223).contains(&i) || (577..=623).contains(&i);
    assert!(planted(k3.idx), "K=3 missed the twins: {}", k3.idx);
    assert!(k3.nn_dist > k1.nn_dist);
}
