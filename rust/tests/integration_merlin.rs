//! End-to-end MERLIN integration: accuracy against planted anomalies on
//! every generator, serial/parallel equivalence, engine equivalence, and
//! the heatmap/ranking pipeline.

use palmad::analysis::heatmap::Heatmap;
use palmad::analysis::ranking::top_k_interesting;
use palmad::baselines::merlin_serial;
use palmad::coordinator::merlin::{Merlin, MerlinConfig, StatsBackend};
use palmad::core::series::TimeSeries;
use palmad::engines::native::NativeEngine;
use palmad::gen::inject::{inject_random, InjectionKind};
use palmad::gen::{ecg, heating, power, random_walk, respiration, shuttle};

fn run_merlin(t: &TimeSeries, min_l: usize, max_l: usize, top_k: usize) -> Vec<palmad::Discord> {
    let engine = NativeEngine::with_segn(128);
    let cfg = MerlinConfig { min_l, max_l, top_k, ..Default::default() };
    Merlin::new(&engine, cfg).run(t).unwrap().all_discords().copied().collect()
}

#[test]
fn finds_planted_anomalies_in_random_walk() {
    let mut t = random_walk::random_walk(8_000, 3);
    // Three *distinct* anomaly shapes: identical injections would be
    // twins (mutually nearest neighbors with small distances) — the
    // classic "twin freak" problem discords are known not to solve (§1).
    let planted = inject_random(
        &mut t,
        3,
        64,
        &[InjectionKind::SpikeTrain, InjectionKind::NoiseBurst, InjectionKind::LevelShift],
        17,
    );
    assert_eq!(planted.len(), 3);
    let discords = run_merlin(&t, 48, 64, 3);
    // At least two of the three planted anomalies must be discovered (the
    // third can legitimately be out-scored by a natural walk discord when
    // its local spike scale lands in a low-variance stretch).
    let found = planted
        .iter()
        .filter(|p| discords.iter().any(|d| p.hit(d.idx, d.m)))
        .count();
    assert!(found >= 2, "only {found}/3 planted anomalies discovered");
    // And the single best discord must be a planted one.
    let top = discords
        .iter()
        .max_by(|a, b| a.nn_dist.partial_cmp(&b.nn_dist).unwrap())
        .unwrap();
    assert!(
        planted.iter().any(|p| p.hit(top.idx, top.m)),
        "top discord at {} is not a planted anomaly",
        top.idx
    );
}

#[test]
fn finds_pvc_in_ecg() {
    let t = ecg::ecg_with_pvc(12_000, 128.0, 70.0, &[40], 5);
    let pvc = ecg::beat_sample(128.0, 70.0, 40);
    let discords = run_merlin(&t, 96, 112, 1);
    let hits = discords.iter().filter(|d| d.idx + d.m > pvc && d.idx < pvc + 250).count();
    assert!(hits * 2 > discords.len(), "{hits}/{}", discords.len());
}

#[test]
fn finds_defective_valve_cycle() {
    let t = shuttle::shuttle_valve(40, 150, &[23], 7);
    let defect_start = 23 * 150;
    let discords = run_merlin(&t, 120, 150, 1);
    let top = discords
        .iter()
        .max_by(|a, b| {
            let na = a.nn_dist / (a.m as f64).sqrt();
            let nb = b.nn_dist / (b.m as f64).sqrt();
            na.partial_cmp(&nb).unwrap()
        })
        .unwrap();
    assert!(
        top.idx + top.m > defect_start && top.idx < defect_start + 300,
        "top discord at {} not in defect cycle {defect_start}",
        top.idx
    );
}

#[test]
fn finds_holiday_in_power_demand() {
    let t = power::power_demand(28, &[9], 9); // day 9 (Wed) is a holiday
    let discords = run_merlin(&t, 96, 96, 1); // one-day windows
    let d = discords[0];
    let holiday = 9 * power::SAMPLES_PER_DAY;
    // The discord window should cover part of the holiday.
    assert!(
        d.idx + d.m > holiday && d.idx < holiday + power::SAMPLES_PER_DAY,
        "discord at {} misses holiday {holiday}",
        d.idx
    );
}

#[test]
fn finds_wake_transition_in_respiration() {
    let t = respiration::respiration(10_000, 10.0, 6_000, 11);
    let discords = run_merlin(&t, 200, 220, 1);
    // The discord should sit near the regime transition (the only
    // non-repeating structure).
    let hits = discords.iter().filter(|d| (5_200..7_000).contains(&d.idx)).count();
    assert!(hits * 2 > discords.len(), "{hits}/{} near transition", discords.len());
}

#[test]
fn serial_merlin_equivalence_on_heating_slice() {
    let (t, _) = heating::heating_year(13);
    let t = t.prefix(4_000);
    let serial = merlin_serial::merlin(&t.values, 24, 32, 1);
    let parallel = {
        let engine = NativeEngine::with_segn(64);
        let cfg = MerlinConfig { min_l: 24, max_l: 32, top_k: 1, ..Default::default() };
        Merlin::new(&engine, cfg).run(&t).unwrap()
    };
    for (s, p) in serial.iter().zip(&parallel.lengths) {
        assert_eq!(s.m, p.m);
        let (sd, pd) = (&s.discords[0], &p.discords[0]);
        assert!(
            (sd.nn_dist - pd.nn_dist).abs() < 1e-6 * (1.0 + sd.nn_dist),
            "m={}: {} vs {}",
            s.m,
            sd.nn_dist,
            pd.nn_dist
        );
    }
}

#[test]
fn aot_stats_backend_equals_native_backend() {
    // Only runs when a PJRT runtime is linked AND artifacts exist (the
    // XLA engine is needed for AOT stats).
    if !palmad::runtime::pjrt_runtime_available() {
        eprintln!("SKIP: PJRT runtime unavailable (offline xla stub build)");
        return;
    }
    let Ok(artifacts) = palmad::runtime::artifact::ArtifactSet::load(
        palmad::runtime::artifact::ArtifactSet::default_dir(),
    ) else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let segn = *artifacts.tile_segns().first().unwrap();
    let engine = palmad::engines::xla::XlaEngine::new(artifacts, segn).unwrap();
    let t = random_walk::random_walk(3_000, 21);
    let base = MerlinConfig { min_l: 32, max_l: 40, top_k: 1, ..Default::default() };
    let native = Merlin::new(&engine, base.clone()).run(&t).unwrap();
    let aot = Merlin::new(
        &engine,
        MerlinConfig { stats_backend: StatsBackend::Aot, ..base },
    )
    .run(&t)
    .unwrap();
    for (a, b) in native.lengths.iter().zip(&aot.lengths) {
        assert_eq!(a.discords[0].idx, b.discords[0].idx, "m={}", a.m);
        assert!((a.discords[0].nn_dist - b.discords[0].nn_dist).abs() < 1e-2);
    }
}

#[test]
fn heatmap_pipeline_surfaces_stuck_sensor() {
    let (t, planted) = heating::heating_year(29);
    let t = t.prefix(10_000);
    let planted: Vec<_> = planted.into_iter().filter(|p| p.start + p.len < 10_000).collect();
    assert!(!planted.is_empty());
    let engine = NativeEngine::with_segn(128);
    let mut lengths = Vec::new();
    for m in [48usize, 96, 192] {
        let cfg = MerlinConfig { min_l: m, max_l: m, top_k: 0, ..Default::default() };
        lengths.extend(Merlin::new(&engine, cfg).run(&t).unwrap().lengths);
    }
    let res = palmad::coordinator::merlin::MerlinResult { lengths, metrics: Default::default() };
    let hm = Heatmap::from_result(&res, t.len());
    let top = top_k_interesting(&hm, 3);
    assert!(!top.is_empty());
    let hit = top.iter().any(|r| {
        planted.iter().any(|p| p.start < r.idx + r.m && r.idx < p.start + p.len)
    });
    assert!(hit, "top-3 interesting discords missed all planted anomalies: {top:?}");
}

#[test]
fn segn_invariance_of_results() {
    let t = random_walk::random_walk(3_000, 33);
    let mut reference: Option<Vec<(usize, u64)>> = None;
    for segn in [32usize, 100, 256, 1024] {
        let engine = NativeEngine::with_segn(segn);
        let cfg = MerlinConfig { min_l: 24, max_l: 28, top_k: 1, ..Default::default() };
        let res = Merlin::new(&engine, cfg).run(&t).unwrap();
        let sig: Vec<(usize, u64)> = res
            .lengths
            .iter()
            .map(|l| (l.discords[0].idx, (l.discords[0].nn_dist * 1e9) as u64))
            .collect();
        match &reference {
            None => reference = Some(sig),
            Some(r) => assert_eq!(r, &sig, "segn={segn} changed the result"),
        }
    }
}
