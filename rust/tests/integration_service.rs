//! Service-level integration: job lifecycle under load, failure isolation,
//! and protocol robustness against malformed input.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::service::{JobSpec, JobState, Service};

fn spec(seed: u64) -> JobSpec {
    JobSpec { dataset: "respiration".into(), n: Some(3_000), seed, min_l: 32, max_l: 36, top_k: 1 }
}

#[test]
fn mixed_success_and_failure_batch() {
    let mut svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 3).unwrap();
    let ok_ids: Vec<u64> = (0..4).map(|k| svc.submit(spec(k))).collect();
    let bad_dataset = svc.submit(JobSpec { dataset: "missing".into(), ..spec(9) });
    let bad_range = svc.submit(JobSpec { min_l: 2_000, max_l: 2_100, ..spec(10) });
    for id in ok_ids {
        match svc.wait(id) {
            Some(JobState::Done { discords, .. }) => assert_eq!(discords.len(), 5),
            other => panic!("job {id}: {other:?}"),
        }
    }
    assert!(matches!(svc.wait(bad_dataset), Some(JobState::Failed(_))));
    assert!(matches!(svc.wait(bad_range), Some(JobState::Failed(_))));
    let (submitted, done, failed, _) = svc.metrics();
    assert_eq!((submitted, done, failed), (6, 4, 2));
    svc.shutdown();
}

#[test]
fn protocol_rejects_garbage_without_dying() {
    let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let svc = std::sync::Arc::new(svc);
    let svc2 = std::sync::Arc::clone(&svc);
    let server = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if svc2.handle_conn_public(stream.unwrap()) {
                break;
            }
        }
    });
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    let mut roundtrip = |conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str| {
        writeln!(conn, "{req}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        line.trim().to_string()
    };
    assert!(roundtrip(&mut conn, &mut reader, "FROBNICATE").starts_with("ERR"));
    assert!(roundtrip(&mut conn, &mut reader, "RUN nonsense").starts_with("ERR"));
    assert!(roundtrip(&mut conn, &mut reader, "RUN gen=ecg2").starts_with("ERR"));
    assert!(roundtrip(&mut conn, &mut reader, "STATUS 999").starts_with("ERR"));
    assert!(roundtrip(&mut conn, &mut reader, "STATUS notanumber").starts_with("ERR"));
    // Still alive for a well-formed request.
    let ok = roundtrip(&mut conn, &mut reader, "RUN gen=respiration n=3000 minl=32 maxl=33 seed=1");
    assert!(ok.starts_with("OK JOB"), "{ok}");
    assert_eq!(roundtrip(&mut conn, &mut reader, "SHUTDOWN"), "OK BYE");
    server.join().unwrap();
}

#[test]
fn many_small_jobs_saturate_workers() {
    let mut svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 4).unwrap();
    let ids: Vec<u64> = (0..12)
        .map(|k| {
            svc.submit(JobSpec {
                dataset: "ecg2".into(),
                n: Some(2_000),
                seed: k,
                min_l: 20,
                max_l: 22,
                top_k: 1,
            })
        })
        .collect();
    let mut total = 0;
    for id in ids {
        match svc.wait(id) {
            Some(JobState::Done { discords, .. }) => total += discords.len(),
            other => panic!("{other:?}"),
        }
    }
    assert_eq!(total, 12 * 3);
    svc.shutdown();
}
