//! Service-level integration: job lifecycle under load, scheduler
//! fairness, failure isolation, the DATA/CANCEL protocol verbs, and
//! robustness against malformed input.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::service::{JobSpec, JobState, Service};

fn spec(seed: u64) -> JobSpec {
    JobSpec {
        dataset: "respiration".into(),
        n: Some(3_000),
        seed,
        min_l: 32,
        max_l: 36,
        top_k: 1,
        ..Default::default()
    }
}

/// In-process accept loop handling each connection on its own thread
/// (the `Service::serve` shape), for tests that drive the TCP surface
/// directly.  A SHUTDOWN on any connection stops the listener.
fn spawn_accept_loop(svc: &Arc<Service>) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    use std::sync::atomic::{AtomicBool, Ordering};
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let svc = Arc::clone(svc);
    let server = std::thread::spawn(move || {
        let stop = Arc::new(AtomicBool::new(false));
        let mut conns = Vec::new();
        for stream in listener.incoming() {
            let stream = stream.unwrap();
            if stop.load(Ordering::Acquire) {
                break;
            }
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            conns.push(std::thread::spawn(move || {
                if svc.handle_conn_public(stream) {
                    stop.store(true, Ordering::Release);
                    let _ = TcpStream::connect(addr); // wake the accept loop
                }
            }));
        }
        for c in conns {
            let _ = c.join();
        }
    });
    (addr, server)
}

struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
    line: String,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let conn = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        Self { conn, reader, line: String::new() }
    }

    fn send(&mut self, req: &str) -> String {
        writeln!(self.conn, "{req}").unwrap();
        self.read_line()
    }

    fn read_line(&mut self) -> String {
        self.line.clear();
        self.reader.read_line(&mut self.line).unwrap();
        self.line.trim().to_string()
    }

    /// RUN …, asserting acceptance; returns the job id.
    fn run(&mut self, req: &str) -> u64 {
        let resp = self.send(req);
        assert!(resp.starts_with("OK JOB "), "{req} -> {resp}");
        resp.rsplit(' ').next().unwrap().parse().unwrap()
    }

    /// Poll STATUS until DONE; returns the DISCORD line count.
    fn wait_done(&mut self, id: u64) -> usize {
        loop {
            let resp = self.send(&format!("STATUS {id}"));
            if resp.starts_with("OK DONE") {
                let mut count = 0;
                loop {
                    let l = self.read_line();
                    if l == "END" {
                        break;
                    }
                    assert!(l.starts_with("DISCORD "), "{l}");
                    count += 1;
                }
                return count;
            }
            assert!(
                resp.starts_with("OK QUEUED") || resp.starts_with("OK RUNNING"),
                "job {id}: {resp}"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }
}

#[test]
fn mixed_success_and_failure_batch() {
    let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 3).unwrap();
    let ok_ids: Vec<u64> = (0..4).map(|k| svc.submit(spec(k)).unwrap()).collect();
    let bad_dataset = svc.submit(JobSpec { dataset: "missing".into(), ..spec(9) }).unwrap();
    let bad_range = svc.submit(JobSpec { min_l: 2_000, max_l: 2_100, ..spec(10) }).unwrap();
    for id in ok_ids {
        match svc.wait(id) {
            Some(JobState::Done { discords, .. }) => assert_eq!(discords.len(), 5),
            other => panic!("job {id}: {other:?}"),
        }
    }
    assert!(matches!(svc.wait(bad_dataset), Some(JobState::Failed(_))));
    assert!(matches!(svc.wait(bad_range), Some(JobState::Failed(_))));
    let (submitted, done, failed, _) = svc.metrics();
    assert_eq!((submitted, done, failed), (6, 4, 2));
    svc.shutdown();
}

#[test]
fn protocol_rejects_garbage_without_dying() {
    let svc =
        Arc::new(Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap());
    let (addr, server) = spawn_accept_loop(&svc);
    let mut c = Client::connect(addr);
    assert!(c.send("FROBNICATE").starts_with("ERR"));
    assert!(c.send("RUN nonsense").starts_with("ERR"));
    assert!(c.send("RUN gen=ecg2").starts_with("ERR"));
    assert!(c.send("STATUS 999").starts_with("ERR"));
    assert!(c.send("STATUS notanumber").starts_with("ERR"));
    assert!(c.send("CANCEL 999").starts_with("ERR"));
    assert!(c.send("FORGET 999").starts_with("ERR"));
    assert!(c.send("DATA name=x").starts_with("ERR"), "DATA without n=");
    // Parse-time validation: rejected before any worker sees the job.
    assert!(c.send("RUN gen=ecg2 n=3000 minl=64 maxl=32").starts_with("ERR"), "minl > maxl");
    assert!(c.send("RUN gen=ecg2 n=3000 minl=2 maxl=32").starts_with("ERR"), "minl < 4");
    assert!(c.send("RUN gen=ecg2 n=3000 minl=32 maxl=40 topk=0").starts_with("ERR"), "topk=0");
    assert!(c.send("RUN gen=ecg2 n=99999999999 minl=32 maxl=40").starts_with("ERR"), "absurd n");
    assert!(c.send("RUN gen=ecg2 n=60 minl=32 maxl=40").starts_with("ERR"), "n < 2*maxl");
    assert!(c.send("RUN data=ghost minl=32 maxl=40").starts_with("ERR"), "unknown upload");
    // Still alive for a well-formed request.
    let ok = c.send("RUN gen=respiration n=3000 minl=32 maxl=33 seed=1");
    assert!(ok.starts_with("OK JOB"), "{ok}");
    // Nothing above ever reached a worker: jobs=1 submitted total.
    let metrics = c.send("METRICS");
    assert!(metrics.contains("jobs=1"), "{metrics}");
    assert_eq!(c.send("SHUTDOWN"), "OK BYE");
    server.join().unwrap();
}

#[test]
fn many_small_jobs_saturate_workers() {
    let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 4).unwrap();
    let ids: Vec<u64> = (0..12)
        .map(|k| {
            svc.submit(JobSpec {
                dataset: "ecg2".into(),
                n: Some(2_000),
                seed: k,
                min_l: 20,
                max_l: 22,
                ..spec(k)
            }).unwrap()
        })
        .collect();
    let mut total = 0;
    for id in ids {
        match svc.wait(id) {
            Some(JobState::Done { discords, .. }) => total += discords.len(),
            other => panic!("{other:?}"),
        }
    }
    assert_eq!(total, 12 * 3);
    svc.shutdown();
}

/// Scheduler-fairness acceptance: one large job and several small jobs
/// submitted together (large first, single worker — the configuration
/// where the old run-to-completion service head-of-line-blocked
/// everything).  Under the step scheduler every small job completes
/// while the large one is still sweeping.
#[test]
fn small_jobs_finish_before_the_large_one() {
    let svc = Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap();
    let large = svc.submit(JobSpec { min_l: 32, max_l: 140, n: Some(4_000), ..spec(1) }).unwrap();
    let small_ids: Vec<u64> = (0..3)
        .map(|k| svc.submit(JobSpec { min_l: 32, max_l: 34, ..spec(k + 2) }).unwrap())
        .collect();
    for id in &small_ids {
        match svc.wait(*id) {
            Some(JobState::Done { discords, .. }) => assert_eq!(discords.len(), 3),
            other => panic!("small job {id}: {other:?}"),
        }
    }
    // The large job (109 lengths) is still going: round-robin stepping
    // let the 3-length jobs through after at most a few of its steps.
    match svc.status(large).unwrap() {
        JobState::Queued | JobState::Running => {}
        other => panic!("large job already terminal: {other:?}"),
    }
    let (done, total) = svc.progress(large).unwrap();
    assert_eq!(total, 109);
    assert!(done < total, "large job must not have finished yet");
    let sm = svc.sched_metrics();
    assert!(sm.preempts >= 3, "small jobs required preemptive requeues: {sm:?}");
    svc.cancel(large).unwrap();
    assert!(matches!(svc.wait(large), Some(JobState::Cancelled)));
    svc.shutdown();
}

/// Protocol end-to-end under concurrency: three clients drive
/// RUN/DATA/STATUS/CANCEL/METRICS simultaneously against one service,
/// and small jobs complete (interleaved) before a deliberately large
/// one finishes.
#[test]
fn three_concurrent_clients_interleave() {
    let svc =
        Arc::new(Service::start(EngineOptions { segn: 64, ..Default::default() }, 2).unwrap());
    let (addr, server) = spawn_accept_loop(&svc);

    // Client A: a large job it will cancel once the others are done.
    let mut a = Client::connect(addr);
    let large = a.run("RUN gen=respiration n=6000 minl=32 maxl=240 seed=1");

    // Clients B and C run concurrently: B uploads a series and sweeps
    // it; C runs small generated jobs.
    let b = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        writeln!(c.conn, "DATA name=mine n=600").unwrap();
        // An obvious anomaly at 300..316 in a sine wave, uploaded in
        // chunks of 100 values per line.
        let vals: Vec<f64> = (0..600)
            .map(|i| {
                let base = (i as f64 * 0.2).sin();
                if (300..316).contains(&i) {
                    base + 3.0
                } else {
                    base
                }
            })
            .collect();
        for chunk in vals.chunks(100) {
            let line: Vec<String> = chunk.iter().map(|v| format!("{v}")).collect();
            writeln!(c.conn, "{}", line.join(" ")).unwrap();
        }
        assert_eq!(c.read_line(), "OK DATA mine n=600");
        let id = c.run("RUN data=mine minl=16 maxl=18 topk=1");
        assert_eq!(c.wait_done(id), 3);
        id
    });
    let c_thread = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        let mut ids = Vec::new();
        for k in 0..3 {
            ids.push(c.run(&format!("RUN gen=ecg2 n=2000 minl=16 maxl=17 seed={k}")));
        }
        for id in &ids {
            assert_eq!(c.wait_done(*id), 2);
        }
        ids
    });

    let b_id = b.join().unwrap();
    let c_ids = c_thread.join().unwrap();
    assert!(!c_ids.contains(&b_id), "job ids are unique across clients");

    // Every small job finished; the 209-length job must still be
    // running — that is the interleaved completion order the step
    // scheduler guarantees.
    let status = a.send(&format!("STATUS {large}"));
    assert!(
        status.starts_with("OK RUNNING") || status.starts_with("OK QUEUED"),
        "large job should still be in flight: {status}"
    );
    assert_eq!(a.send(&format!("CANCEL {large}")), format!("OK CANCELLED {large}"));
    // The cancel lands at the next step boundary.
    loop {
        let s = a.send(&format!("STATUS {large}"));
        if s == "OK CANCELLED" {
            break;
        }
        assert!(s.starts_with("OK RUNNING"), "{s}");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let metrics = a.send("METRICS");
    assert!(metrics.contains("done=4"), "{metrics}");
    assert!(metrics.contains("cancelled=1"), "{metrics}");
    assert!(metrics.contains("uploads=1"), "{metrics}");
    assert!(metrics.contains("sched(steps/preempts/leases)="), "{metrics}");
    assert_eq!(a.send("SHUTDOWN"), "OK BYE");
    drop(a);
    server.join().unwrap();
    svc.shutdown();
}

/// Graceful-drain satellite, via an in-process listener: SHUTDOWN over
/// the wire lets in-flight steps finish, fails queued jobs with
/// "shutdown", and joins the workers (handle_conn_public reports the
/// request; the embedder calls Service::shutdown, as serve() does).
#[test]
fn tcp_shutdown_drains_queued_jobs() {
    let svc =
        Arc::new(Service::start(EngineOptions { segn: 64, ..Default::default() }, 1).unwrap());
    let (addr, server) = spawn_accept_loop(&svc);
    let mut c = Client::connect(addr);
    let ids: Vec<u64> = (0..4)
        .map(|k| c.run(&format!("RUN gen=respiration n=4000 minl=32 maxl=140 seed={k}")))
        .collect();
    assert_eq!(c.send("SHUTDOWN"), "OK BYE");
    server.join().unwrap();
    svc.shutdown(); // the drain the serve() accept loop would run
    let mut failed = 0;
    for id in ids {
        match svc.status(id).unwrap() {
            JobState::Failed(msg) if msg == "shutdown" => failed += 1,
            JobState::Done { .. } => {}
            other => panic!("job {id} after drain: {other:?}"),
        }
    }
    assert!(failed >= 3, "queued jobs must fail with 'shutdown', got {failed}");
    // Workers are joined: a second shutdown is a no-op and the service
    // accepts no more steps.
    svc.shutdown();
}
