//! End-to-end check of the AOT bridge: the Pallas/JAX tile and stats
//! kernels (compiled to HLO text by `make artifacts`) must agree with the
//! native f64 engine on identical inputs.
//!
//! Requires artifacts; skipped (with a loud note) when
//! `artifacts/manifest.txt` is missing so plain `cargo test` still works
//! before the first `make artifacts`.

use palmad::core::stats::RollingStats;
use palmad::coordinator::drag::{pd3, Pd3Config};
use palmad::coordinator::metrics::DragMetrics;
use palmad::engines::native::{compute_tile, NativeEngine};
use palmad::engines::{Engine, SeriesView, TileTask};
use palmad::runtime::artifact::ArtifactSet;
use palmad::engines::xla::XlaEngine;
use palmad::util::rng::Rng;

/// Gate: these tests need both compiled AOT artifacts *and* a linked
/// PJRT runtime (the offline `xla` stub reports unavailable).  Without
/// either, skip loudly so `cargo test -q` stays green everywhere.
fn artifacts() -> Option<ArtifactSet> {
    if !palmad::runtime::pjrt_runtime_available() {
        eprintln!(
            "SKIP: PJRT runtime unavailable (offline xla stub build); \
             link the real xla bindings to run the AOT roundtrip tests"
        );
        return None;
    }
    let dir = ArtifactSet::default_dir();
    match ArtifactSet::load(&dir) {
        Ok(s) => Some(s),
        Err(_) => {
            eprintln!("SKIP: no artifacts in {dir:?}; run `make artifacts`");
            None
        }
    }
}

fn random_walk(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed(seed);
    let mut acc = 0.0;
    (0..n)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect()
}

/// Compare XLA tile outputs against the native engine within f32 slack.
fn compare_tiles(t: &[f64], m: usize, segn: usize, r2: f64, tasks: &[TileTask], xla: &XlaEngine) {
    let stats = RollingStats::compute(t, m);
    let view = SeriesView { t, stats: &stats };
    let got = xla.compute_tiles(&view, r2, tasks).unwrap();
    for (k, task) in tasks.iter().enumerate() {
        let want = compute_tile(&view, segn, r2, *task);
        for i in 0..segn {
            let (g, w) = (got[k].row_min[i], want.row_min[i]);
            assert_eq!(g.is_finite(), w.is_finite(), "task {k} row {i} finiteness: {g} vs {w}");
            if w.is_finite() {
                // f32 kernel vs f64 native: tolerance scales with m.
                let tol = 2e-3 * (1.0 + w);
                assert!((g - w).abs() < tol, "task {k} row {i}: {g} vs {w}");
            }
            let (g, w) = (got[k].col_min[i], want.col_min[i]);
            assert_eq!(g.is_finite(), w.is_finite(), "task {k} col {i} finiteness");
            if w.is_finite() {
                let tol = 2e-3 * (1.0 + w);
                assert!((g - w).abs() < tol, "task {k} col {i}: {g} vs {w}");
            }
            // Kill flags may legitimately differ within f32 slack of the
            // threshold; only check where the native distance is clearly
            // on one side.
            let margin = 1e-3 * (1.0 + r2);
            if want.row_min[i].is_finite() && (want.row_min[i] - r2).abs() > margin {
                assert_eq!(got[k].row_kill[i], want.row_kill[i], "task {k} row_kill {i}");
            }
        }
    }
}

#[test]
fn tile_kernel_matches_native_engine() {
    let Some(set) = artifacts() else { return };
    let segn = *set.tile_segns().first().expect("tile artifacts");
    let xla = XlaEngine::new(set, segn).unwrap();
    let t = random_walk(1200, 42);
    let m = 50;
    let tasks = vec![
        TileTask { seg_start: 0, chunk_start: 0 },      // self tile
        TileTask { seg_start: 0, chunk_start: segn },   // adjacent
        TileTask { seg_start: segn, chunk_start: 640 }, // disjoint
        TileTask { seg_start: 640, chunk_start: 0 },    // left scan
        TileTask { seg_start: 1100, chunk_start: 0 },   // ragged tail rows
    ];
    compare_tiles(&t, m, segn, 30.0, &tasks, &xla);
}

#[test]
fn tile_kernel_handles_flat_windows() {
    let Some(set) = artifacts() else { return };
    let segn = *set.tile_segns().first().unwrap();
    let xla = XlaEngine::new(set, segn).unwrap();
    let mut t = random_walk(800, 7);
    for v in &mut t[300..450] {
        *v = 21.5; // stuck sensor
    }
    let tasks = vec![
        TileTask { seg_start: 256, chunk_start: 384 },
        TileTask { seg_start: 320, chunk_start: 320 },
    ];
    compare_tiles(&t, 40, segn, 10.0, &tasks, &xla);
}

#[test]
fn aot_stats_match_native() {
    let Some(set) = artifacts() else { return };
    let segn = *set.tile_segns().first().unwrap();
    let xla = XlaEngine::new(set, segn).unwrap();
    let t = random_walk(5000, 9);
    let m = 64;
    let aot = xla.aot_stats_init(&t, m).unwrap();
    let native = RollingStats::compute(&t, m);
    assert_eq!(aot.len(), native.len());
    for i in 0..native.len() {
        // f32 series input limits the agreement.
        assert!((aot.mu[i] - native.mu[i]).abs() < 1e-3 * (1.0 + native.mu[i].abs()), "mu {i}");
        assert!((aot.sig[i] - native.sig[i]).abs() < 1e-2 * (1.0 + native.sig[i]), "sig {i}");
    }
    // Recurrent update (Eqs. 7/8) via the Pallas kernel.
    let aot2 = xla.aot_stats_update(&t, &aot).unwrap();
    let native2 = RollingStats::compute(&t, m + 1);
    assert_eq!(aot2.m, m + 1);
    assert_eq!(aot2.len(), native2.len());
    for i in 0..native2.len() {
        assert!((aot2.mu[i] - native2.mu[i]).abs() < 1e-3 * (1.0 + native2.mu[i].abs()));
        assert!((aot2.sig[i] - native2.sig[i]).abs() < 1e-2 * (1.0 + native2.sig[i]));
    }
}

#[test]
fn pd3_same_discords_on_both_engines() {
    let Some(set) = artifacts() else { return };
    let segn = *set.tile_segns().first().unwrap();
    let xla = XlaEngine::new(set, segn).unwrap();
    let native = NativeEngine::with_segn(segn);
    let t = random_walk(3000, 77);
    let m = 48;
    let stats = RollingStats::compute(&t, m);
    let view = SeriesView { t: &t, stats: &stats };
    let r = 3.0;
    let cfg = Pd3Config::default();
    let mut mx = DragMetrics::default();
    let mut mn = DragMetrics::default();
    let mut dx = pd3(&xla, &view, r, &cfg, &mut mx).unwrap();
    let mut dn = pd3(&native, &view, r, &cfg, &mut mn).unwrap();
    dx.sort_by_key(|d| d.idx);
    dn.sort_by_key(|d| d.idx);
    let ix: Vec<usize> = dx.iter().map(|d| d.idx).collect();
    let i_n: Vec<usize> = dn.iter().map(|d| d.idx).collect();
    assert_eq!(ix, i_n, "survivor sets differ between engines");
    for (a, b) in dx.iter().zip(&dn) {
        assert!((a.nn_dist - b.nn_dist).abs() < 1e-2 * (1.0 + b.nn_dist));
    }
}
