//! Differential conformance harness for the tile kernels.
//!
//! Every f64 lane kernel (`Lanes4`, `Lanes8`, and whatever `Auto`
//! resolves to) is *claimed* to be bit-identical to the `Scalar` oracle
//! (same per-element operation order at every width; the only
//! reductions — `min` with `+inf` identities, boolean OR — are
//! insensitive to lane regrouping).  This suite pins that claim rather
//! than hoping for it:
//!
//! - a property sweep over random series shapes, subsequence lengths,
//!   and tile widths deliberately off the lane grid (`segn % LANES !=
//!   0`, `segn < LANES` — and, for `Lanes8`, `segn < 8` — plus
//!   single-column/single-row tail tiles), asserting each lane kernel
//!   matches the scalar oracle **bit-for-bit** — which is, a fortiori,
//!   inside the issue's 1-ULP tolerance;
//! - engine-level batch conformance including the clamp-decision
//!   counters (`EnginePerfCounters::{clamp_saturations, flat_cells}`)
//!   on constant-window, NaN-contaminated, and near-overflow inputs;
//! - full `Merlin::run` discord output, identical under every f64
//!   kernel.
//!
//! `TileKernel::Lanes4F32` is the deliberate exception: it runs the
//! same lane bodies one precision down, so its contract is the
//! **tolerance band** `band(m) = 2m * (m + 8) * KAPPA * eps_f32`
//! (EXPERIMENTS.md §SIMD derives it), valid on series with
//! `max|t|^2 / min(sigma)^2 <= KAPPA = 4096`.  The banded comparator
//! below — minima both infinite or within `band(m)`, kill flags
//! compared only outside a `band(m)` margin around `r2`, flat routing
//! exactly equal (flat decisions stay in f64 by construction) — is the
//! reusable gate a reduced-precision accelerator engine will face, and
//! a seeded ill-conditioned series proves it has teeth.
//!
//! `scripts/ci.sh --kernel-matrix` additionally re-runs this whole file
//! (and the allocation suite) under `PALMAD_TILE_KERNEL=<k>` for every
//! kernel in `engines::KERNEL_NAMES` (lanes8 skipped on hosts without
//! AVX-512F), flipping every engine built with default config.

use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::core::distance::is_flat;
use palmad::core::series::TimeSeries;
use palmad::core::stats::RollingStats;
use palmad::engines::native::{compute_tile_with_kernel, NativeConfig, NativeEngine};
use palmad::engines::{Engine, SeriesView, TileKernel, TileTask, LANES};
use palmad::runtime::types::TileOutputs;
use palmad::testkit::{check, Config, SeriesGen};
use palmad::util::rng::Rng;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|d| d.to_bits()).collect()
}

fn assert_tiles_bit_equal(a: &TileOutputs, b: &TileOutputs, what: &str) {
    assert_eq!(bits(&a.row_min), bits(&b.row_min), "{what}: row_min");
    assert_eq!(bits(&a.col_min), bits(&b.col_min), "{what}: col_min");
    assert_eq!(a.row_kill, b.row_kill, "{what}: row_kill");
    assert_eq!(a.col_kill, b.col_kill, "{what}: col_kill");
}

/// Tile widths the sweep draws from: below LANES, off the lane grid,
/// exactly on it, and comfortably above it.
const EDGES: [usize; 10] = [1, 2, 3, LANES, 5, 7, 13, 31, 33, 64];

#[test]
fn prop_lane_kernel_matches_scalar_oracle_bitwise() {
    check("lane-vs-scalar", Config { cases: 50, ..Default::default() }, |rng| {
        let n = rng.int_in(60, 400);
        let kind = SeriesGen::random(rng);
        let t = kind.generate(n, rng);
        let m = rng.int_in(3, (n / 3).min(40));
        let nwin = n - m + 1;
        let segn = EDGES[rng.below(EDGES.len())];
        let r2 = rng.range(0.1, 4.0 * m as f64);
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        // Self tile, random tiles, and tail tiles whose live width /
        // height is 1 (the hardest tail-loop cases).
        let mut tasks = vec![
            TileTask { seg_start: 0, chunk_start: 0 },
            TileTask { seg_start: 0, chunk_start: nwin - 1 },
            TileTask { seg_start: nwin - 1, chunk_start: 0 },
        ];
        for _ in 0..3 {
            tasks.push(TileTask { seg_start: rng.below(nwin), chunk_start: rng.below(nwin) });
        }
        for task in tasks {
            let s = compute_tile_with_kernel(&view, segn, r2, task, TileKernel::Scalar);
            for kern in [TileKernel::Lanes4, TileKernel::Lanes8] {
                let l = compute_tile_with_kernel(&view, segn, r2, task, kern);
                // Bit equality first (the strong claim)...
                assert_tiles_bit_equal(
                    &s,
                    &l,
                    &format!("{kern:?} {kind:?} n={n} m={m} segn={segn} {task:?}"),
                );
                // ...which subsumes the issue's ULP-scale tolerance; keep
                // an explicit tolerance pass anyway so a future deliberate
                // bit-divergence (e.g. FMA lanes) inherits a ready gate.
                for k in 0..segn {
                    let (g, w) = (l.row_min[k], s.row_min[k]);
                    if w.is_finite() {
                        assert!(
                            (g - w).abs() <= 1e-12 * (1.0 + w.abs()),
                            "{kern:?} m={m} segn={segn} row {k}: {g} vs {w}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn engine_batches_agree_for_every_edge_width() {
    // Fixed workload, every off-grid edge, multi-threaded batches: the
    // pooled path must agree with itself across kernels, and the clamp
    // gauges must match exactly.
    let mut rng = Rng::seed(2024);
    let t = SeriesGen::Walk.generate(600, &mut rng);
    let m = 19;
    let stats = RollingStats::compute(&t, m);
    let view = SeriesView { t: &t, stats: &stats };
    let nwin = view.n_windows();
    for segn in EDGES {
        let mk = |kernel| {
            NativeEngine::new(NativeConfig { segn, threads: 4, kernel, ..Default::default() })
        };
        let scalar = mk(TileKernel::Scalar);
        let tasks: Vec<TileTask> = (0..10)
            .map(|k| TileTask {
                seg_start: (k * 83) % nwin,
                chunk_start: (k * 131 + 7) % nwin,
            })
            .collect();
        scalar.prepare_series(&view);
        let a = scalar.compute_tiles(&view, 5.0, &tasks).unwrap();
        let ca = scalar.perf_counters();
        for kern in [TileKernel::Lanes4, TileKernel::Lanes8] {
            let lanes = mk(kern);
            lanes.prepare_series(&view);
            let b = lanes.compute_tiles(&view, 5.0, &tasks).unwrap();
            for (k, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_tiles_bit_equal(x, y, &format!("{kern:?} segn={segn} task {k}"));
            }
            let cb = lanes.perf_counters();
            assert_eq!(
                ca.clamp_saturations, cb.clamp_saturations,
                "{kern:?} segn={segn}: clamp decisions diverged"
            );
            assert_eq!(ca.flat_cells, cb.flat_cells, "{kern:?} segn={segn}: flat routing diverged");
        }
    }
}

/// The clamp-path edge cases of the issue checklist: constant
/// (zero-variance) windows, NaN-contaminated windows, and near-overflow
/// values, pushed through both kernels with the decision counters as
/// the certificate.
#[test]
fn clamp_edge_cases_take_identical_decisions() {
    let mut rng = Rng::seed(77);
    let n = 400;
    let m = 16;
    // Case 1: stuck sensor — long constant run (flat path, sigma floor).
    let mut constant = SeriesGen::Walk.generate(n, &mut rng);
    for v in &mut constant[120..260] {
        *v = -3.25;
    }
    // Case 2: NaN contamination — NaN windows stat a NaN mean and a
    // floored sigma, classify flat, and must route identically.
    let mut nan = SeriesGen::Walk.generate(n, &mut rng);
    for v in &mut nan[200..210] {
        *v = f64::NAN;
    }
    // Case 3: near-overflow magnitudes — dot products around 1e300; the
    // Eq. 6 cancellation goes wild but both kernels share every rounding.
    let overflow: Vec<f64> =
        (0..n).map(|i| 1.0e150 * (1.0 + 0.5 * ((i as f64) * 0.37).sin())).collect();
    for (name, t) in [("constant", &constant), ("nan", &nan), ("overflow", &overflow)] {
        let stats = RollingStats::compute(t, m);
        let view = SeriesView { t, stats: &stats };
        let nwin = view.n_windows();
        let mk = |kernel| {
            NativeEngine::new(NativeConfig { segn: 33, threads: 2, kernel, ..Default::default() })
        };
        let scalar = mk(TileKernel::Scalar);
        let tasks: Vec<TileTask> = (0..nwin.div_ceil(33))
            .flat_map(|r| {
                (0..nwin.div_ceil(33)).map(move |c| TileTask {
                    seg_start: r * 33,
                    chunk_start: c * 33,
                })
            })
            .collect();
        scalar.prepare_series(&view);
        let a = scalar.compute_tiles(&view, 3.0, &tasks).unwrap();
        let ca = scalar.perf_counters();
        if name != "overflow" {
            assert!(ca.flat_cells > 0, "{name}: flat path never exercised");
        }
        for kern in [TileKernel::Lanes4, TileKernel::Lanes8] {
            let lanes = mk(kern);
            lanes.prepare_series(&view);
            let b = lanes.compute_tiles(&view, 3.0, &tasks).unwrap();
            for (k, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_tiles_bit_equal(x, y, &format!("{kern:?} {name} task {k}"));
                // The edge inputs must stay semantically sane, not just
                // consistent: minima are +inf or finite >= 0, never NaN.
                for &d in x.row_min.iter().chain(&x.col_min) {
                    assert!(!d.is_nan() && d >= 0.0, "{name} task {k}: bad min {d}");
                }
            }
            let cb = lanes.perf_counters();
            assert_eq!(
                (ca.clamp_saturations, ca.flat_cells),
                (cb.clamp_saturations, cb.flat_cells),
                "{kern:?} {name}: decision counters diverged"
            );
        }
    }
}

#[test]
fn merlin_discords_identical_across_kernels() {
    // Full arbitrary-length discovery — the end-to-end wiring of the
    // kernel choice.  Same workload as the long-green
    // `finds_discords_for_every_length` unit test, run under both
    // kernels: every per-length result must agree exactly (indices,
    // bit-level distances, thresholds, retry counts).
    let mut rng = Rng::seed(21);
    let mut acc = 0.0;
    let values: Vec<f64> = (0..600)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect();
    let t = TimeSeries::new("rw", values);
    let cfg = MerlinConfig { min_l: 16, max_l: 32, top_k: 2, ..Default::default() };
    let run = |kernel| {
        let engine = NativeEngine::new(NativeConfig {
            segn: 64,
            kernel,
            ..Default::default()
        });
        Merlin::new(&engine, cfg.clone()).run(&t).unwrap()
    };
    let a = run(TileKernel::Scalar);
    for kern in [TileKernel::Lanes4, TileKernel::Lanes8, TileKernel::Auto] {
        let b = run(kern);
        assert_eq!(a.lengths.len(), b.lengths.len());
        for (x, y) in a.lengths.iter().zip(&b.lengths) {
            assert_eq!(x.m, y.m);
            assert_eq!(x.retries, y.retries, "{kern:?} m={}", x.m);
            assert_eq!(x.r_used.to_bits(), y.r_used.to_bits(), "{kern:?} m={}", x.m);
            assert_eq!(x.discords.len(), y.discords.len(), "{kern:?} m={}", x.m);
            for (dx, dy) in x.discords.iter().zip(&y.discords) {
                assert_eq!(dx.idx, dy.idx, "{kern:?} m={}", x.m);
                assert_eq!(
                    dx.nn_dist.to_bits(),
                    dy.nn_dist.to_bits(),
                    "{kern:?} m={}: {} vs {}",
                    x.m,
                    dx.nn_dist,
                    dy.nn_dist
                );
            }
        }
        // The counter-level certificate at MERLIN scale — and, for Auto,
        // the METRICS visibility of the resolved identity.
        let (sa, sb) = (&a.metrics.seed, &b.metrics.seed);
        assert_eq!(sa.clamp_saturations, sb.clamp_saturations, "{kern:?}");
        assert_eq!(sa.flat_cells, sb.flat_cells, "{kern:?}");
        assert_eq!(sb.kernel, Some(kern.resolve()), "{kern:?} identity gauge");
        let line = format!("{}", b.metrics);
        assert!(
            line.contains(&format!("kernel={}", kern.resolve().name())),
            "{kern:?}: resolved kernel missing from METRICS line: {line}"
        );
    }
}

#[test]
fn prop_merlin_agrees_across_kernels_on_random_series() {
    check("merlin-kernel-agreement", Config { cases: 6, ..Default::default() }, |rng| {
        let n = rng.int_in(200, 360);
        let kind = SeriesGen::random(rng);
        let t = TimeSeries::new("prop", kind.generate(n, rng));
        let min_l = rng.int_in(8, 14);
        let max_l = min_l + rng.int_in(2, 6);
        if n < 2 * max_l {
            return Ok(()); // degenerate draw; MERLIN would reject both
        }
        // segn >= 32 keeps the whole sweep's QT-seed key count far below
        // the cache's per-shard bound: with overflow, *which* rows stay
        // cached is scheduling-dependent, and an evicted row re-seeds
        // fresh at the next length (different rounding from an advanced
        // row) — that would make bit-equality scheduling-dependent too.
        // Small/off-grid edges are covered by the tile-level sweep above.
        let segn = EDGES[rng.below(EDGES.len())].max(32);
        let cfg = MerlinConfig { min_l, max_l, top_k: 1, max_retries: 20, ..Default::default() };
        let run = |kernel| {
            let engine =
                NativeEngine::new(NativeConfig { segn, kernel, ..Default::default() });
            Merlin::new(&engine, cfg.clone()).run(&t)
        };
        let a = run(TileKernel::Scalar).map_err(|e| format!("scalar: {e}"))?;
        let wide = if rng.below(2) == 0 { TileKernel::Lanes4 } else { TileKernel::Lanes8 };
        let b = run(wide).map_err(|e| format!("{wide:?}: {e}"))?;
        for (x, y) in a.lengths.iter().zip(&b.lengths) {
            if x.discords.len() != y.discords.len() {
                return Err(format!(
                    "{kind:?} n={n} segn={segn} m={}: {} vs {} discords",
                    x.m,
                    x.discords.len(),
                    y.discords.len()
                ));
            }
            for (dx, dy) in x.discords.iter().zip(&y.discords) {
                if dx.idx != dy.idx || dx.nn_dist.to_bits() != dy.nn_dist.to_bits() {
                    return Err(format!(
                        "{kind:?} n={n} segn={segn} m={}: ({}, {}) vs ({}, {})",
                        x.m, dx.idx, dx.nn_dist, dy.idx, dy.nn_dist
                    ));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Lanes4F32: the tolerance-banded contract (and Auto's resolution).
// ---------------------------------------------------------------------------

/// Conditioning headroom the f32 kernel is specified for: series with
/// `max|t|^2 <= KAPPA * min(sigma)^2` over non-flat windows (flat
/// windows route through the f64 general path regardless of kernel).
/// EXPERIMENTS.md §SIMD derives the pairing with [`band`].
const KAPPA: f64 = 4096.0;

/// Absolute error bound on a squared z-normalized distance computed at
/// f32 for subsequence length `m`, valid on series inside the [`KAPPA`]
/// precondition.
fn band(m: usize) -> f64 {
    let mf = m as f64;
    2.0 * mf * (mf + 8.0) * KAPPA * f64::from(f32::EPSILON)
}

/// Is the series inside the f32 kernel's specified conditioning range?
fn in_f32_spec(t: &[f64], stats: &RollingStats) -> bool {
    let tmax = t.iter().fold(0.0f64, |a, &x| a.max(x.abs()));
    let sig_min = stats
        .sig
        .iter()
        .zip(&stats.mu)
        .filter(|&(&s, &u)| !is_flat(s, u))
        .map(|(&s, _)| s)
        .fold(f64::INFINITY, f64::min);
    sig_min.is_finite() && tmax * tmax <= KAPPA * sig_min * sig_min
}

/// The banded comparator: f32 tile vs f64 oracle tile.
///
/// Minima must be both non-finite or within [`band`]; kill flags are
/// threshold comparisons, so they are decidable only when the oracle
/// minimum clears `r2` by more than the band — inside the margin either
/// decision is acceptable.  This is the exact gate a reduced-precision
/// accelerator engine will be held to.
fn assert_tiles_banded(f: &TileOutputs, o: &TileOutputs, r2: f64, m: usize, what: &str) {
    let eps = band(m);
    let mins: [(&str, &[f64], &[f64]); 2] =
        [("row_min", &f.row_min, &o.row_min), ("col_min", &f.col_min, &o.col_min)];
    for (which, gs, ws) in mins {
        for (k, (&g, &w)) in gs.iter().zip(ws.iter()).enumerate() {
            if w.is_finite() {
                assert!(
                    g.is_finite() && (g - w).abs() <= eps,
                    "{what} {which}[{k}]: {g} vs {w} (band {eps:.3e})"
                );
            } else {
                assert!(!g.is_finite(), "{what} {which}[{k}]: finite {g} vs {w}");
            }
        }
    }
    let kills: [(&str, &[bool], &[f64]); 2] =
        [("row_kill", &f.row_kill, &o.row_min), ("col_kill", &f.col_kill, &o.col_min)];
    for (which, gs, ws) in kills {
        for (k, (&g, &w)) in gs.iter().zip(ws.iter()).enumerate() {
            if w < r2 - eps {
                assert!(g, "{what} {which}[{k}]: f64 min {w} clears r2={r2} but f32 did not kill");
            } else if w > r2 + eps {
                assert!(!g, "{what} {which}[{k}]: f64 min {w} above r2={r2} but f32 killed");
            }
        }
    }
}

#[test]
fn auto_resolves_to_a_cached_bit_identical_f64_kernel() {
    let resolved = TileKernel::Auto.resolve();
    assert!(
        matches!(resolved, TileKernel::Lanes4 | TileKernel::Lanes8),
        "Auto must resolve to an f64 lane kernel, got {resolved:?}"
    );
    assert_eq!(resolved, TileKernel::Auto.resolve(), "resolution must be stable across calls");
    let mut rng = Rng::seed(404);
    let t = SeriesGen::Walk.generate(300, &mut rng);
    let stats = RollingStats::compute(&t, 12);
    let view = SeriesView { t: &t, stats: &stats };
    let task = TileTask { seg_start: 0, chunk_start: 50 };
    let a = compute_tile_with_kernel(&view, 33, 4.0, task, TileKernel::Auto);
    let r = compute_tile_with_kernel(&view, 33, 4.0, task, resolved);
    let s = compute_tile_with_kernel(&view, 33, 4.0, task, TileKernel::Scalar);
    assert_tiles_bit_equal(&a, &r, "auto vs its resolution");
    assert_tiles_bit_equal(&a, &s, "auto vs scalar oracle");
}

#[test]
fn prop_f32_kernel_stays_within_band_of_the_oracle() {
    check("f32-band", Config { cases: 50, ..Default::default() }, |rng| {
        let n = rng.int_in(60, 400);
        let kind = SeriesGen::random(rng);
        let t = kind.generate(n, rng);
        let m = rng.int_in(3, (n / 3).min(40));
        let nwin = n - m + 1;
        let stats = RollingStats::compute(&t, m);
        if !in_f32_spec(&t, &stats) {
            return Ok(()); // outside the documented KAPPA precondition
        }
        let segn = EDGES[rng.below(EDGES.len())];
        let r2 = rng.range(0.1, 4.0 * m as f64);
        let view = SeriesView { t: &t, stats: &stats };
        let mut tasks = vec![
            TileTask { seg_start: 0, chunk_start: 0 },
            TileTask { seg_start: 0, chunk_start: nwin - 1 },
            TileTask { seg_start: nwin - 1, chunk_start: 0 },
        ];
        for _ in 0..3 {
            tasks.push(TileTask { seg_start: rng.below(nwin), chunk_start: rng.below(nwin) });
        }
        for task in tasks {
            let s = compute_tile_with_kernel(&view, segn, r2, task, TileKernel::Scalar);
            let f = compute_tile_with_kernel(&view, segn, r2, task, TileKernel::Lanes4F32);
            assert_tiles_banded(
                &f,
                &s,
                r2,
                m,
                &format!("{kind:?} n={n} m={m} segn={segn} {task:?}"),
            );
        }
        Ok(())
    });
}

#[test]
fn f32_engine_decisions_match_on_margin_workloads() {
    // Off-diagonal tasks only: near-diagonal cells (|a - b| < m) sit at
    // corr ~ 1, where the clamp decision is a precision coin flip even
    // though the cells are masked afterwards — so keep them out of the
    // counted set entirely.  On what remains (iid noise far from the
    // plateau), correlations are bounded away from ±1, and flat routing
    // is decided on f64 stats under both kernels: the decision counters
    // must agree exactly.
    let mut rng = Rng::seed(99);
    let mut t = SeriesGen::Noise.generate(600, &mut rng);
    for v in &mut t[400..500] {
        *v = 2.5; // stuck sensor: flat columns → shared f64 flat path
    }
    let m = 16;
    let stats = RollingStats::compute(&t, m);
    let view = SeriesView { t: &t, stats: &stats };
    let tasks = vec![
        TileTask { seg_start: 0, chunk_start: 300 },
        TileTask { seg_start: 33, chunk_start: 396 },
        TileTask { seg_start: 0, chunk_start: 462 },
        TileTask { seg_start: 66, chunk_start: 528 },
    ];
    let mk = |kernel| {
        NativeEngine::new(NativeConfig { segn: 33, threads: 2, kernel, ..Default::default() })
    };
    let f64e = mk(TileKernel::Lanes4);
    let f32e = mk(TileKernel::Lanes4F32);
    f64e.prepare_series(&view);
    f32e.prepare_series(&view);
    let a = f64e.compute_tiles(&view, 6.0, &tasks).unwrap();
    let b = f32e.compute_tiles(&view, 6.0, &tasks).unwrap();
    for (k, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_tiles_banded(y, x, 6.0, m, &format!("task {k}"));
    }
    let (ca, cb) = (f64e.perf_counters(), f32e.perf_counters());
    assert_eq!(ca.flat_cells, cb.flat_cells, "flat routing must be kernel-invariant");
    assert!(ca.flat_cells > 0, "plateau tiles must exercise the flat path");
    assert_eq!(
        ca.clamp_saturations, cb.clamp_saturations,
        "margin workload: clamp decisions must agree"
    );
    assert_eq!(cb.kernel, Some(TileKernel::Lanes4F32), "identity gauge");
}

#[test]
fn f32_top_discord_index_matches_f64_on_well_conditioned_series() {
    // On an in-spec series with one strongly planted anomaly, the f32
    // kernel must rank the *same* top discord per length (index
    // equality; distances only band-close).  Retry trajectories may
    // diverge inside the band, so only the ranked result is pinned.
    let mut rng = Rng::seed(5150);
    let mut values: Vec<f64> =
        (0..600).map(|i| (i as f64 * 0.23).sin() + 0.02 * rng.normal()).collect();
    for (k, v) in values[300..318].iter_mut().enumerate() {
        // A violent period-2 zig-zag: categorically unlike both the
        // carrier sine and (after the m-wide exclusion zone) every
        // other window, so the top-1 margin dwarfs band(m).
        *v += if k % 2 == 0 { 2.5 } else { -2.5 };
    }
    let stats = RollingStats::compute(&values, 16);
    assert!(in_f32_spec(&values, &stats), "workload must sit inside the f32 spec");
    let t = TimeSeries::new("anomaly", values);
    let cfg = MerlinConfig { min_l: 16, max_l: 24, top_k: 1, max_retries: 30, ..Default::default() };
    let run = |kernel| {
        let engine = NativeEngine::new(NativeConfig { segn: 64, kernel, ..Default::default() });
        Merlin::new(&engine, cfg.clone()).run(&t).unwrap()
    };
    let a = run(TileKernel::Lanes4);
    let b = run(TileKernel::Lanes4F32);
    assert_eq!(a.lengths.len(), b.lengths.len());
    for (x, y) in a.lengths.iter().zip(&b.lengths) {
        assert_eq!(x.m, y.m);
        assert!(!x.discords.is_empty() && !y.discords.is_empty(), "m={}: no discord", x.m);
        let (dx, dy) = (&x.discords[0], &y.discords[0]);
        assert_eq!(dx.idx, dy.idx, "m={}: top discord moved under f32", x.m);
        assert!(
            (dx.nn_dist - dy.nn_dist).abs() <= band(x.m),
            "m={}: {} vs {} (band {:.3e})",
            x.m,
            dx.nn_dist,
            dy.nn_dist,
            band(x.m)
        );
        assert!(
            dx.idx >= 280 && dx.idx < 320,
            "m={}: top discord {} is not at the planted anomaly",
            x.m,
            dx.idx
        );
    }
}

#[test]
fn band_comparator_has_teeth_on_an_ill_conditioned_series() {
    // Negative control: a ~1e7 offset with sigma ~ 1e2 puts
    // max|t|^2 / sigma^2 ~ 1e10 >> KAPPA, so the f32 QT cancellation is
    // catastrophic — the f32 ulp at qt ~ 1.6e15 is ~1.3e8, larger than
    // the entire covariance term (~1.6e5), leaving the f32 correlation
    // as pure quantization noise.  The banded comparator must be able
    // to reject this: at least one row minimum lands farther than
    // band(m) from the oracle.  (Also pins that the spec predicate
    // itself classifies the series as out of range.)
    let mut rng = Rng::seed(31337);
    let t: Vec<f64> = (0..300).map(|_| 1.0e7 + 100.0 * rng.normal()).collect();
    let m = 16;
    let stats = RollingStats::compute(&t, m);
    assert!(!in_f32_spec(&t, &stats), "control must violate the KAPPA precondition");
    // ...while still dodging the flat classifier (sigma ~ 100 >>
    // FLAT_EPS * 1e7 = 10), so the fast f32 path really runs.
    assert!(stats.sig.iter().zip(&stats.mu).all(|(&s, &u)| !is_flat(s, u)));
    let view = SeriesView { t: &t, stats: &stats };
    let task = TileTask { seg_start: 0, chunk_start: 120 };
    let s = compute_tile_with_kernel(&view, 33, 6.0, task, TileKernel::Scalar);
    let f = compute_tile_with_kernel(&view, 33, 6.0, task, TileKernel::Lanes4F32);
    let eps = band(m);
    let worst = s
        .row_min
        .iter()
        .zip(&f.row_min)
        .filter(|(w, _)| w.is_finite())
        .map(|(&w, &g)| (g - w).abs())
        .fold(0.0f64, f64::max);
    assert!(worst > eps, "expected out-of-band divergence, worst {worst} <= band {eps}");
}
