//! Differential conformance harness for the tile kernels.
//!
//! `TileKernel::Lanes4` is *claimed* to be bit-identical to the
//! `Scalar` oracle (same per-element operation order; the only
//! reductions — `min` with `+inf` identities, boolean OR — are
//! insensitive to lane regrouping).  This suite pins that claim rather
//! than hoping for it:
//!
//! - a property sweep over random series shapes, subsequence lengths,
//!   and tile widths deliberately off the lane grid (`segn % LANES !=
//!   0`, `segn < LANES`, single-column/single-row tail tiles), asserting
//!   the lane kernel matches the scalar oracle **bit-for-bit** — which
//!   is, a fortiori, inside the issue's 1-ULP tolerance;
//! - engine-level batch conformance including the clamp-decision
//!   counters (`EnginePerfCounters::{clamp_saturations, flat_cells}`)
//!   on constant-window, NaN-contaminated, and near-overflow inputs;
//! - full `Merlin::run` discord output, identical under both kernels.
//!
//! `scripts/ci.sh --kernel-matrix` additionally re-runs this whole file
//! (and the allocation suite) under `PALMAD_TILE_KERNEL=scalar` and
//! `=lanes4`, flipping every engine built with default config.

use palmad::coordinator::merlin::{Merlin, MerlinConfig};
use palmad::core::series::TimeSeries;
use palmad::core::stats::RollingStats;
use palmad::engines::native::{compute_tile_with_kernel, NativeConfig, NativeEngine};
use palmad::engines::{Engine, SeriesView, TileKernel, TileTask, LANES};
use palmad::runtime::types::TileOutputs;
use palmad::testkit::{check, Config, SeriesGen};
use palmad::util::rng::Rng;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|d| d.to_bits()).collect()
}

fn assert_tiles_bit_equal(a: &TileOutputs, b: &TileOutputs, what: &str) {
    assert_eq!(bits(&a.row_min), bits(&b.row_min), "{what}: row_min");
    assert_eq!(bits(&a.col_min), bits(&b.col_min), "{what}: col_min");
    assert_eq!(a.row_kill, b.row_kill, "{what}: row_kill");
    assert_eq!(a.col_kill, b.col_kill, "{what}: col_kill");
}

/// Tile widths the sweep draws from: below LANES, off the lane grid,
/// exactly on it, and comfortably above it.
const EDGES: [usize; 10] = [1, 2, 3, LANES, 5, 7, 13, 31, 33, 64];

#[test]
fn prop_lane_kernel_matches_scalar_oracle_bitwise() {
    check("lane-vs-scalar", Config { cases: 50, ..Default::default() }, |rng| {
        let n = rng.int_in(60, 400);
        let kind = SeriesGen::random(rng);
        let t = kind.generate(n, rng);
        let m = rng.int_in(3, (n / 3).min(40));
        let nwin = n - m + 1;
        let segn = EDGES[rng.below(EDGES.len())];
        let r2 = rng.range(0.1, 4.0 * m as f64);
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        // Self tile, random tiles, and tail tiles whose live width /
        // height is 1 (the hardest tail-loop cases).
        let mut tasks = vec![
            TileTask { seg_start: 0, chunk_start: 0 },
            TileTask { seg_start: 0, chunk_start: nwin - 1 },
            TileTask { seg_start: nwin - 1, chunk_start: 0 },
        ];
        for _ in 0..3 {
            tasks.push(TileTask { seg_start: rng.below(nwin), chunk_start: rng.below(nwin) });
        }
        for task in tasks {
            let s = compute_tile_with_kernel(&view, segn, r2, task, TileKernel::Scalar);
            let l = compute_tile_with_kernel(&view, segn, r2, task, TileKernel::Lanes4);
            // Bit equality first (the strong claim)...
            assert_tiles_bit_equal(
                &s,
                &l,
                &format!("{kind:?} n={n} m={m} segn={segn} {task:?}"),
            );
            // ...which subsumes the issue's ULP-scale tolerance; keep an
            // explicit tolerance pass anyway so a future deliberate
            // bit-divergence (e.g. FMA lanes) inherits a ready gate.
            for k in 0..segn {
                let (g, w) = (l.row_min[k], s.row_min[k]);
                if w.is_finite() {
                    assert!(
                        (g - w).abs() <= 1e-12 * (1.0 + w.abs()),
                        "m={m} segn={segn} row {k}: {g} vs {w}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn engine_batches_agree_for_every_edge_width() {
    // Fixed workload, every off-grid edge, multi-threaded batches: the
    // pooled path must agree with itself across kernels, and the clamp
    // gauges must match exactly.
    let mut rng = Rng::seed(2024);
    let t = SeriesGen::Walk.generate(600, &mut rng);
    let m = 19;
    let stats = RollingStats::compute(&t, m);
    let view = SeriesView { t: &t, stats: &stats };
    let nwin = view.n_windows();
    for segn in EDGES {
        let mk = |kernel| {
            NativeEngine::new(NativeConfig { segn, threads: 4, kernel, ..Default::default() })
        };
        let scalar = mk(TileKernel::Scalar);
        let lanes = mk(TileKernel::Lanes4);
        let tasks: Vec<TileTask> = (0..10)
            .map(|k| TileTask {
                seg_start: (k * 83) % nwin,
                chunk_start: (k * 131 + 7) % nwin,
            })
            .collect();
        scalar.prepare_series(&view);
        lanes.prepare_series(&view);
        let a = scalar.compute_tiles(&view, 5.0, &tasks).unwrap();
        let b = lanes.compute_tiles(&view, 5.0, &tasks).unwrap();
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_tiles_bit_equal(x, y, &format!("segn={segn} task {k}"));
        }
        let (ca, cb) = (scalar.perf_counters(), lanes.perf_counters());
        assert_eq!(
            ca.clamp_saturations, cb.clamp_saturations,
            "segn={segn}: clamp decisions diverged"
        );
        assert_eq!(ca.flat_cells, cb.flat_cells, "segn={segn}: flat routing diverged");
    }
}

/// The clamp-path edge cases of the issue checklist: constant
/// (zero-variance) windows, NaN-contaminated windows, and near-overflow
/// values, pushed through both kernels with the decision counters as
/// the certificate.
#[test]
fn clamp_edge_cases_take_identical_decisions() {
    let mut rng = Rng::seed(77);
    let n = 400;
    let m = 16;
    // Case 1: stuck sensor — long constant run (flat path, sigma floor).
    let mut constant = SeriesGen::Walk.generate(n, &mut rng);
    for v in &mut constant[120..260] {
        *v = -3.25;
    }
    // Case 2: NaN contamination — NaN windows stat a NaN mean and a
    // floored sigma, classify flat, and must route identically.
    let mut nan = SeriesGen::Walk.generate(n, &mut rng);
    for v in &mut nan[200..210] {
        *v = f64::NAN;
    }
    // Case 3: near-overflow magnitudes — dot products around 1e300; the
    // Eq. 6 cancellation goes wild but both kernels share every rounding.
    let overflow: Vec<f64> =
        (0..n).map(|i| 1.0e150 * (1.0 + 0.5 * ((i as f64) * 0.37).sin())).collect();
    for (name, t) in [("constant", &constant), ("nan", &nan), ("overflow", &overflow)] {
        let stats = RollingStats::compute(t, m);
        let view = SeriesView { t, stats: &stats };
        let nwin = view.n_windows();
        let mk = |kernel| {
            NativeEngine::new(NativeConfig { segn: 33, threads: 2, kernel, ..Default::default() })
        };
        let scalar = mk(TileKernel::Scalar);
        let lanes = mk(TileKernel::Lanes4);
        let tasks: Vec<TileTask> = (0..nwin.div_ceil(33))
            .flat_map(|r| {
                (0..nwin.div_ceil(33)).map(move |c| TileTask {
                    seg_start: r * 33,
                    chunk_start: c * 33,
                })
            })
            .collect();
        scalar.prepare_series(&view);
        lanes.prepare_series(&view);
        let a = scalar.compute_tiles(&view, 3.0, &tasks).unwrap();
        let b = lanes.compute_tiles(&view, 3.0, &tasks).unwrap();
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_tiles_bit_equal(x, y, &format!("{name} task {k}"));
            // The edge inputs must stay semantically sane, not just
            // consistent: minima are +inf or finite >= 0, never NaN.
            for &d in x.row_min.iter().chain(&x.col_min) {
                assert!(!d.is_nan() && d >= 0.0, "{name} task {k}: bad min {d}");
            }
        }
        let (ca, cb) = (scalar.perf_counters(), lanes.perf_counters());
        assert_eq!(
            (ca.clamp_saturations, ca.flat_cells),
            (cb.clamp_saturations, cb.flat_cells),
            "{name}: decision counters diverged"
        );
        if name != "overflow" {
            assert!(ca.flat_cells > 0, "{name}: flat path never exercised");
        }
    }
}

#[test]
fn merlin_discords_identical_across_kernels() {
    // Full arbitrary-length discovery — the end-to-end wiring of the
    // kernel choice.  Same workload as the long-green
    // `finds_discords_for_every_length` unit test, run under both
    // kernels: every per-length result must agree exactly (indices,
    // bit-level distances, thresholds, retry counts).
    let mut rng = Rng::seed(21);
    let mut acc = 0.0;
    let values: Vec<f64> = (0..600)
        .map(|_| {
            acc += rng.normal();
            acc
        })
        .collect();
    let t = TimeSeries::new("rw", values);
    let cfg = MerlinConfig { min_l: 16, max_l: 32, top_k: 2, ..Default::default() };
    let run = |kernel| {
        let engine = NativeEngine::new(NativeConfig {
            segn: 64,
            kernel,
            ..Default::default()
        });
        Merlin::new(&engine, cfg.clone()).run(&t).unwrap()
    };
    let a = run(TileKernel::Scalar);
    let b = run(TileKernel::Lanes4);
    assert_eq!(a.lengths.len(), b.lengths.len());
    for (x, y) in a.lengths.iter().zip(&b.lengths) {
        assert_eq!(x.m, y.m);
        assert_eq!(x.retries, y.retries, "m={}", x.m);
        assert_eq!(x.r_used.to_bits(), y.r_used.to_bits(), "m={}", x.m);
        assert_eq!(x.discords.len(), y.discords.len(), "m={}", x.m);
        for (dx, dy) in x.discords.iter().zip(&y.discords) {
            assert_eq!(dx.idx, dy.idx, "m={}", x.m);
            assert_eq!(
                dx.nn_dist.to_bits(),
                dy.nn_dist.to_bits(),
                "m={}: {} vs {}",
                x.m,
                dx.nn_dist,
                dy.nn_dist
            );
        }
    }
    // The counter-level certificate at MERLIN scale.
    let (sa, sb) = (a.metrics.seed, b.metrics.seed);
    assert_eq!(sa.clamp_saturations, sb.clamp_saturations);
    assert_eq!(sa.flat_cells, sb.flat_cells);
}

#[test]
fn prop_merlin_agrees_across_kernels_on_random_series() {
    check("merlin-kernel-agreement", Config { cases: 6, ..Default::default() }, |rng| {
        let n = rng.int_in(200, 360);
        let kind = SeriesGen::random(rng);
        let t = TimeSeries::new("prop", kind.generate(n, rng));
        let min_l = rng.int_in(8, 14);
        let max_l = min_l + rng.int_in(2, 6);
        if n < 2 * max_l {
            return Ok(()); // degenerate draw; MERLIN would reject both
        }
        // segn >= 32 keeps the whole sweep's QT-seed key count far below
        // the cache's per-shard bound: with overflow, *which* rows stay
        // cached is scheduling-dependent, and an evicted row re-seeds
        // fresh at the next length (different rounding from an advanced
        // row) — that would make bit-equality scheduling-dependent too.
        // Small/off-grid edges are covered by the tile-level sweep above.
        let segn = EDGES[rng.below(EDGES.len())].max(32);
        let cfg = MerlinConfig { min_l, max_l, top_k: 1, max_retries: 20, ..Default::default() };
        let run = |kernel| {
            let engine =
                NativeEngine::new(NativeConfig { segn, kernel, ..Default::default() });
            Merlin::new(&engine, cfg.clone()).run(&t)
        };
        let a = run(TileKernel::Scalar).map_err(|e| format!("scalar: {e}"))?;
        let b = run(TileKernel::Lanes4).map_err(|e| format!("lanes4: {e}"))?;
        for (x, y) in a.lengths.iter().zip(&b.lengths) {
            if x.discords.len() != y.discords.len() {
                return Err(format!(
                    "{kind:?} n={n} segn={segn} m={}: {} vs {} discords",
                    x.m,
                    x.discords.len(),
                    y.discords.len()
                ));
            }
            for (dx, dy) in x.discords.iter().zip(&y.discords) {
                if dx.idx != dy.idx || dx.nn_dist.to_bits() != dy.nn_dist.to_bits() {
                    return Err(format!(
                        "{kind:?} n={n} segn={segn} m={}: ({}, {}) vs ({}, {})",
                        x.m, dx.idx, dx.nn_dist, dy.idx, dy.nn_dist
                    ));
                }
            }
        }
        Ok(())
    });
}
