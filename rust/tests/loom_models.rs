//! Bounded model checking of the concurrency core (CONCURRENCY.md).
//!
//! Compiled and run only under `RUSTFLAGS="--cfg palmad_loom"`:
//!
//! ```text
//! RUSTFLAGS="--cfg palmad_loom" cargo test --test loom_models --release
//! ```
//!
//! (or `scripts/ci.sh --loom`).  Each test wraps a scenario in
//! `loom::model`, which explores every interleaving of the loom threads
//! it spawns, up to the preemption bound (`PALMAD_LOOM_PREEMPTIONS`,
//! default 2 — the CHESS result: almost all real concurrency bugs
//! manifest within two forced preemptions).  The production types
//! themselves are explored — `util::loomsync` swaps their `std::sync`
//! primitives for the vendored checker under this cfg — not hand-copied
//! sketches, with two exceptions documented below (`SliceWriter`
//! scenarios live in `util::pool::loom_scenarios` because the type is
//! crate-private, and the `Service` shutdown protocol is distilled
//! because the real service spawns `std` listener/worker threads the
//! checker cannot schedule).
//!
//! Model inventory (referenced by name from CONCURRENCY.md and module
//! docs):
//!
//! | model                                   | protocol under test                 |
//! |-----------------------------------------|-------------------------------------|
//! | `slice_writer_disjoint_publication`     | disjoint slot writes + join publish |
//! | `round_pool_round_completes`            | broadcast/claim/done round handoff  |
//! | `round_pool_disjoint_slots`             | cursor-claimed `SliceWriter` slots  |
//! | `qt_seed_cache_rebind_during_read`      | shard epoch/bound rebind protocol   |
//! | `engine_pool_sticky_vs_steal`           | sticky checkout vs concurrent lease |
//! | `engine_pool_blocked_checkout_wakes`    | condvar wakeup on lease return      |
//! | `sync_poison_recovery_no_lost_wakeup`   | `lock_recover`/`wait_recover` under |
//! |                                         | a poisoned mutex                    |
//! | `service_shutdown_no_lost_wakeup`       | stop-flag store under queue mutex   |
//! | `service_submit_vs_shutdown`            | submit's stop check under the queue |
//! |                                         | mutex (no stranded QUEUED jobs)     |
//!
//! Two negative tests (`*_is_caught`) run deliberately broken protocols
//! and assert the checker fails them — they keep the passing models
//! honest (a checker that cannot find the seeded bug proves nothing).

#![cfg(palmad_loom)]

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

use palmad::coordinator::config::EngineOptions;
use palmad::coordinator::lease::EnginePool;
use palmad::engines::scratch::QtSeedCache;
use palmad::util::loomsync::atomic::{AtomicBool, Ordering};
use palmad::util::loomsync::{thread, Arc, Condvar, Mutex};
use palmad::util::pool::loom_scenarios;
use palmad::util::sync::{lock_recover, wait_recover};

/// Run a model whose explored schedules panic *by design* (deliberate
/// poisoning, seeded protocol bugs) with the default panic hook
/// silenced, so thousands of intentional backtraces do not drown the
/// test log.  The hook is always restored before returning.  Models are
/// globally serialized inside `loom::model`, and the checker prints
/// failing schedules straight to stderr (not via the hook), so genuine
/// failures remain visible.
fn model_outcome(f: impl Fn()) -> std::thread::Result<()> {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let result = catch_unwind(AssertUnwindSafe(|| loom::model(f)));
    std::panic::set_hook(prev);
    result
}

// ---------------------------------------------------------------------
// SliceWriter + RoundPool (scenario bodies in util::pool::loom_scenarios)
// ---------------------------------------------------------------------

#[test]
fn slice_writer_disjoint_publication() {
    loom::model(loom_scenarios::slice_writer_disjoint_publication);
}

#[test]
fn slice_writer_double_claim_is_caught() {
    let result = model_outcome(loom_scenarios::slice_writer_aliased_claim);
    assert!(result.is_err(), "two claims of one slot must fail the model");
}

#[test]
fn round_pool_round_completes() {
    loom::model(loom_scenarios::round_pool_round_completes);
}

#[test]
fn round_pool_disjoint_slots() {
    loom::model(loom_scenarios::round_pool_disjoint_slots);
}

// ---------------------------------------------------------------------
// QtSeedCache rebind protocol (engines/scratch.rs)
// ---------------------------------------------------------------------

/// Reference dot products for window `a` against the `nb` subsequences
/// starting at `cs`.  All model values are small integers, so every
/// product and sum is exact in f64 and the asserts can demand equality.
fn dots(t: &[f64], m: usize, a: usize, cs: usize, nb: usize) -> Vec<f64> {
    (0..nb).map(|j| (0..m).map(|k| t[a + k] * t[cs + j + k]).sum()).collect()
}

#[test]
fn qt_seed_cache_rebind_during_read() {
    loom::model(|| {
        let (m, a, cs, nb) = (3usize, 0usize, 3usize, 2usize);
        let cache = Arc::new(QtSeedCache::new());
        // Arc<Vec<_>> keeps each buffer (and so its (ptr, len) identity)
        // stable for the whole model.
        let t1: Arc<Vec<f64>> = Arc::new(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let t2: Arc<Vec<f64>> = Arc::new(vec![8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        cache.prepare(&t1);
        // Warm a cached row so the racing rebind contends with a live
        // entry, not just a cold miss.
        let mut warm = vec![0.0; nb];
        cache.seed_into(&t1, m, a, cs, nb, &mut warm);

        let rebinder = {
            let (cache, t2) = (Arc::clone(&cache), Arc::clone(&t2));
            thread::spawn(move || cache.prepare(&t2))
        };
        // A read racing the sentinel → epoch-bump → evict → rebind
        // sequence must still produce t1's exact products, recomputing
        // from scratch if its row was evicted mid-flight.
        let mut out = vec![0.0; nb];
        cache.seed_into(&t1, m, a, cs, nb, &mut out);
        assert_eq!(out, dots(&t1, m, a, cs, nb), "reader racing a rebind saw poisoned rows");
        rebinder.join().expect("rebinder completes");

        // After the rebind settles, t2 reads must be exact too: a row
        // cached under the t1 binding must never be served for t2.
        cache.prepare(&t2);
        let mut out2 = vec![0.0; nb];
        cache.seed_into(&t2, m, a, cs, nb, &mut out2);
        assert_eq!(out2, dots(&t2, m, a, cs, nb), "stale t1 row survived the rebind");
    });
}

// ---------------------------------------------------------------------
// EnginePool checkout protocol (coordinator/lease.rs)
// ---------------------------------------------------------------------

fn small_pool(capacity: usize) -> EnginePool {
    let opts = EngineOptions { segn: 32, threads: 1, ..Default::default() };
    EnginePool::new(&opts, capacity).expect("engine pool builds")
}

#[test]
fn engine_pool_sticky_vs_steal() {
    loom::model(|| {
        let pool = Arc::new(small_pool(2));
        // Key one slot to tenant 1, then race tenant 1's sticky
        // re-checkout against tenant 2's first checkout.
        drop(pool.checkout(1));
        let other = {
            let pool = Arc::clone(&pool);
            thread::spawn(move || drop(pool.checkout(2)))
        };
        drop(pool.checkout(1));
        other.join().expect("tenant 2 completes");
        let c = pool.counters();
        assert_eq!(c.leases, 3);
        assert_eq!(c.sticky_hits, 1, "tenant 1's re-checkout must hit its keyed slot");
        assert_eq!(c.rebinds, 0, "two tenants over two slots must never steal");
        // Epilogue: a third tenant on a fully-keyed pool has no sticky
        // and no unkeyed slot left — the LRU steal path must fire.
        drop(pool.checkout(3));
        assert_eq!(pool.counters().rebinds, 1, "tenant 3 must steal the LRU entry");
    });
}

#[test]
fn engine_pool_blocked_checkout_wakes() {
    loom::model(|| {
        let pool = Arc::new(small_pool(1));
        let held = pool.checkout(1);
        let waiter = {
            let pool = Arc::clone(&pool);
            thread::spawn(move || drop(pool.checkout(2)))
        };
        // Lease return re-inserts the entry and notifies *under the
        // slots lock*; every schedule must wake the blocked waiter.
        drop(held);
        waiter.join().expect("blocked checkout must be woken by the returned lease");
        let c = pool.counters();
        assert_eq!(c.leases, 2);
        assert_eq!(c.rebinds, 1, "capacity-1 handoff rebinds the slot to tenant 2");
    });
}

// ---------------------------------------------------------------------
// util::sync poison recovery
// ---------------------------------------------------------------------

#[test]
fn sync_poison_recovery_no_lost_wakeup() {
    // The poisoner panics by design on every explored schedule — run
    // with the hook silenced (see `model_outcome`).
    let result = model_outcome(|| {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        // A worker panics while holding the lock, poisoning it.
        let poisoner = {
            let pair = Arc::clone(&pair);
            thread::spawn(move || {
                let _g = pair.0.lock().unwrap_or_else(|e| e.into_inner());
                panic!("deliberate: poison the flag mutex");
            })
        };
        assert!(poisoner.join().is_err(), "poisoner must have panicked");
        // A second worker sets the flag under the (now poisoned) lock
        // and notifies while still holding it.
        let setter = {
            let pair = Arc::clone(&pair);
            thread::spawn(move || {
                let mut flag = lock_recover(&pair.0);
                *flag = true;
                pair.1.notify_all();
            })
        };
        // The waiter recovers from poison at every acquisition and must
        // still observe the flag; a lost wakeup deadlocks the model.
        let mut flag = lock_recover(&pair.0);
        while !*flag {
            flag = wait_recover(&pair.1, flag);
        }
        drop(flag);
        setter.join().expect("setter completes");
    });
    assert!(result.is_ok(), "poison recovery must not lose the wakeup: {result:?}");
}

// ---------------------------------------------------------------------
// Service shutdown handoff (coordinator/service.rs)
// ---------------------------------------------------------------------

/// Distilled `Service` queue protocol: `worker_main`'s
/// lock → check-stop → pop → wait loop, `submit`'s push-under-lock +
/// notify-after, and `shutdown`'s store + broadcast + join.  Distilled
/// (rather than the real `Service`) because the service spawns `std`
/// listener/worker threads the checker cannot schedule; the loop bodies
/// mirror `coordinator/service.rs` line for line.
///
/// `store_stop_under_queue_lock` selects the fixed (`true`) or pre-PR-7
/// (`false`) shutdown: storing `stop` and notifying *without* the queue
/// mutex can fire between a worker's stop check and its `wait`, after
/// which the worker sleeps forever and `join` never returns.
fn service_shutdown_protocol(store_stop_under_queue_lock: bool) {
    let queue: Arc<Mutex<VecDeque<u64>>> = Arc::new(Mutex::new(VecDeque::new()));
    let cv = Arc::new(Condvar::new());
    let stop = Arc::new(AtomicBool::new(false));
    let worker = {
        let (queue, cv, stop) = (Arc::clone(&queue), Arc::clone(&cv), Arc::clone(&stop));
        thread::spawn(move || loop {
            let job: u64 = {
                let mut q = lock_recover(&queue);
                loop {
                    if stop.load(Ordering::Acquire) {
                        return;
                    }
                    if let Some(id) = q.pop_front() {
                        break id;
                    }
                    q = wait_recover(&cv, q);
                }
            };
            let _ = job; // "run" the job outside the lock
        })
    };
    // Service::submit — push under the queue lock, notify after (safe:
    // the predicate change happened under the waiters' mutex, so the
    // worker either sees the job or is parked when the notify lands).
    lock_recover(&queue).push_back(7);
    cv.notify_one();
    // Service::shutdown.
    if store_stop_under_queue_lock {
        let _q = lock_recover(&queue);
        stop.store(true, Ordering::Release);
        cv.notify_all();
    } else {
        stop.store(true, Ordering::Release);
        cv.notify_all();
    }
    worker.join().expect("worker must observe shutdown");
}

#[test]
fn service_shutdown_no_lost_wakeup() {
    loom::model(|| service_shutdown_protocol(true));
}

#[test]
fn service_shutdown_lost_wakeup_bug_is_caught() {
    // Regression pin for the PR 7 fix: the old protocol must deadlock
    // under some schedule (the checker reports it as a failed model).
    let result = model_outcome(|| service_shutdown_protocol(false));
    assert!(result.is_err(), "the unfixed shutdown protocol must deadlock under the model");
}

/// Distilled `Service::submit` vs `Service::shutdown` (PR 9 fix).
/// Job lifecycle: 0 = not yet in the jobs table, 1 = tabled and
/// non-terminal (QUEUED), 2 = terminal.  `submit` tables the job, then
/// under the queue mutex either enqueues it (stop unseen) or observes
/// `stop` and self-finalizes as `Failed("shutdown")`.  `shutdown`
/// stores `stop` under the queue mutex, drains the queue, and
/// finalizes every tabled non-terminal job.
///
/// The invariant: once both complete, the job is terminal and the
/// queue is empty — no schedule may strand a QUEUED job that no worker
/// will ever pop.  `check_stop_under_queue_lock = false` replays the
/// pre-PR-9 submit (enqueue with no stop check): a submit that lands
/// after shutdown's drain leaves the job QUEUED forever, which the
/// checker must catch.
fn service_submit_protocol(check_stop_under_queue_lock: bool) {
    let queue: Arc<Mutex<VecDeque<u64>>> = Arc::new(Mutex::new(VecDeque::new()));
    let cv = Arc::new(Condvar::new());
    let stop = Arc::new(AtomicBool::new(false));
    let job = Arc::new(Mutex::new(0u8));

    let submitter = {
        let (queue, cv, stop, job) =
            (Arc::clone(&queue), Arc::clone(&cv), Arc::clone(&stop), Arc::clone(&job));
        thread::spawn(move || {
            // Jobs-table insert happens-before the id is queued (a
            // popped id missing from the table is dropped as forgotten).
            *lock_recover(&job) = 1;
            if check_stop_under_queue_lock {
                let stopped = {
                    let mut q = lock_recover(&queue);
                    if stop.load(Ordering::Acquire) {
                        true
                    } else {
                        q.push_back(7);
                        cv.notify_one();
                        false
                    }
                };
                if stopped {
                    // Self-finalize: Failed("shutdown"), unless the
                    // drain pass got there first.
                    let mut j = lock_recover(&job);
                    if *j == 1 {
                        *j = 2;
                    }
                }
            } else {
                // Pre-PR-9 submit: unconditional enqueue.
                lock_recover(&queue).push_back(7);
                cv.notify_one();
            }
        })
    };
    // Service::shutdown.
    {
        let _q = lock_recover(&queue);
        stop.store(true, Ordering::Release);
        cv.notify_all();
    }
    // (worker joins happen here in the real service)
    lock_recover(&queue).clear();
    {
        let mut j = lock_recover(&job);
        if *j == 1 {
            *j = 2;
        }
    }
    submitter.join().expect("submitter completes");
    assert_eq!(*lock_recover(&job), 2, "job stranded QUEUED with no worker to pop it");
    assert!(lock_recover(&queue).is_empty(), "drained queue must stay empty");
}

#[test]
fn service_submit_vs_shutdown() {
    loom::model(|| service_submit_protocol(true));
}

#[test]
fn service_submit_unchecked_enqueue_bug_is_caught() {
    // Regression pin for the PR 9 fix: the old submit (no stop check
    // under the queue mutex) must strand a job under some schedule.
    let result = model_outcome(|| service_submit_protocol(false));
    assert!(result.is_err(), "the unfixed submit protocol must strand a QUEUED job");
}
