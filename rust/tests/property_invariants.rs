//! Property-based invariant suites (DESIGN.md §6) over randomized series
//! shapes: walks, noise, periodic, flat plateaus, huge offsets.

use palmad::baselines::{brute, drag_serial};
use palmad::coordinator::distributed::{distributed_drag, ExchangeMode};
use palmad::coordinator::drag::{pd3, Pd3Config};
use palmad::coordinator::metrics::DragMetrics;
use palmad::coordinator::segmentation::Segmentation;
use palmad::core::distance::{ed2norm, max_ed};
use palmad::core::stats::RollingStats;
use palmad::engines::native::NativeEngine;
use palmad::engines::SeriesView;
use palmad::testkit::{check, Config, SeriesGen};
use palmad::util::rng::Rng;

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Eqs. 7/8: chained recurrent stats equal fresh per-length stats for any
/// series shape and any number of steps.
#[test]
fn prop_stats_recurrence_exact() {
    check("stats-recurrence", Config { cases: 40, ..Default::default() }, |rng| {
        let n = rng.int_in(40, 400);
        let kind = SeriesGen::random(rng);
        let t = kind.generate(n, rng);
        let m0 = rng.int_in(2, (n / 4).max(3).min(40));
        let steps = rng.int_in(1, (n - m0 - 1).min(30));
        let mut s = RollingStats::compute(&t, m0);
        for _ in 0..steps {
            s.advance(&t);
        }
        let fresh = RollingStats::naive(&t, m0 + steps);
        for i in 0..fresh.len() {
            if !close(s.mu[i], fresh.mu[i], 1e-8) {
                return Err(format!("{kind:?} n={n} m0={m0} steps={steps} mu[{i}]: {} vs {}", s.mu[i], fresh.mu[i]));
            }
            // 1e-4: LargeOffset series lose ~11 digits to the E[x^2]-mu^2
            // cancellation; recurrence and two-pass round differently.
            if !close(s.sig[i], fresh.sig[i], 1e-4) {
                return Err(format!("{kind:?} n={n} m0={m0} steps={steps} sig[{i}]: {} vs {}", s.sig[i], fresh.sig[i]));
            }
        }
        Ok(())
    });
}

/// Distance bounds: 0 <= ED^2 <= 4m for any window pair, and symmetry.
#[test]
fn prop_distance_bounds_and_symmetry() {
    check("distance-bounds", Config { cases: 60, ..Default::default() }, |rng| {
        let m = rng.int_in(3, 64);
        let kind = SeriesGen::random(rng);
        // i <= m-1, j <= i + 2m - 1, so j + m <= 4m - 2 < 4m.
        let t = kind.generate(4 * m, rng);
        let i = rng.below(m);
        let j = i + m + rng.below(m);
        let a = &t[i..i + m];
        let b = &t[j..j + m];
        let d1 = ed2norm(a, b);
        let d2 = ed2norm(b, a);
        if !(d1 >= 0.0 && d1 <= max_ed(m).powi(2) + 1e-6) {
            return Err(format!("{kind:?} m={m}: out of bounds d={d1}"));
        }
        if !close(d1, d2, 1e-12) {
            return Err(format!("asymmetry {d1} vs {d2}"));
        }
        Ok(())
    });
}

/// PD3 == serial DRAG == brute force for arbitrary r and segn, including
/// flat-plateau and large-offset series.
#[test]
fn prop_pd3_equals_serial_and_brute() {
    check("pd3-vs-oracles", Config { cases: 25, ..Default::default() }, |rng| {
        let n = rng.int_in(80, 260);
        let kind = SeriesGen::random(rng);
        let t = kind.generate(n, rng);
        let m = rng.int_in(4, (n / 4).min(24));
        let r_frac = rng.range(0.05, 1.1);
        let r = r_frac * max_ed(m);
        let segn = rng.int_in(4, 80);

        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(segn);
        let mut metrics = DragMetrics::default();
        let cfg = Pd3Config {
            deferred_neighbor_kill: rng.chance(0.5),
            early_stop: rng.chance(0.9),
        };
        let mut par = pd3(&engine, &view, r, &cfg, &mut metrics)
            .map_err(|e| format!("pd3: {e}"))?;
        par.sort_by_key(|d| d.idx);

        let serial = drag_serial::drag(&t, m, r);
        let mut want = brute::range_discords(&t, m, r);
        want.sort_by_key(|d| d.idx);

        let pi: Vec<usize> = par.iter().map(|d| d.idx).collect();
        let si: Vec<usize> = serial.iter().map(|d| d.idx).collect();
        let wi: Vec<usize> = want.iter().map(|d| d.idx).collect();
        if pi != wi {
            return Err(format!("{kind:?} n={n} m={m} r={r:.3} segn={segn}: pd3 {pi:?} vs brute {wi:?}"));
        }
        if si != wi {
            return Err(format!("{kind:?} n={n} m={m} r={r:.3}: serial {si:?} vs brute {wi:?}"));
        }
        // 1e-4: the Eq. 6 dot-product form and the direct znorm form round
        // differently under large offsets (both are exact up to f64
        // cancellation; see DESIGN.md §6).
        for (g, w) in par.iter().zip(&want) {
            if !close(g.nn_dist, w.nn_dist, 1e-4) {
                return Err(format!("nnDist at {}: {} vs {}", g.idx, g.nn_dist, w.nn_dist));
            }
        }
        Ok(())
    });
}

/// Survivors of PD3 always satisfy the range-discord definition
/// (nnDist >= r), and every non-survivor has a match closer than r.
#[test]
fn prop_pd3_survivor_definition() {
    check("pd3-survivor-def", Config { cases: 20, ..Default::default() }, |rng| {
        let n = rng.int_in(80, 200);
        let t = SeriesGen::random(rng).generate(n, rng);
        let m = rng.int_in(4, 16);
        let r = rng.range(0.2, 0.9) * max_ed(m);
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(16);
        let mut metrics = DragMetrics::default();
        let found = pd3(&engine, &view, r, &Pd3Config::default(), &mut metrics)
            .map_err(|e| format!("{e}"))?;
        let nn = brute::nn_profile(&t, m);
        let found_idx: std::collections::HashSet<usize> = found.iter().map(|d| d.idx).collect();
        for (i, &d2) in nn.iter().enumerate() {
            let is_discord = d2.is_finite() && d2 >= r * r;
            if is_discord != found_idx.contains(&i) {
                return Err(format!("window {i}: nn2={d2}, r2={}, in set: {}", r * r, found_idx.contains(&i)));
            }
        }
        Ok(())
    });
}

/// Distributed DRAG: both exchange modes (Yankov raw-candidate exchange
/// and Zymbler local refinement) return exactly the brute-force
/// range-discord set on random walks for any partition count / tile
/// edge, and local refinement never puts more candidates on the wire.
#[test]
fn prop_distributed_exchange_modes_match_brute() {
    check("distributed-exchange", Config { cases: 15, ..Default::default() }, |rng| {
        let n = rng.int_in(80, 240);
        let mut acc = 0.0;
        let t: Vec<f64> = (0..n)
            .map(|_| {
                acc += rng.normal();
                acc
            })
            .collect();
        let m = rng.int_in(4, 16);
        let r = rng.range(0.25, 0.95) * max_ed(m);
        let segn = rng.int_in(4, 40);
        let parts = rng.int_in(1, 6);
        let engine = NativeEngine::with_segn(segn);
        let (gy, my) = distributed_drag(&engine, &t, m, r, parts, ExchangeMode::Yankov)
            .map_err(|e| format!("yankov: {e}"))?;
        let (gl, ml) = distributed_drag(&engine, &t, m, r, parts, ExchangeMode::LocalRefine)
            .map_err(|e| format!("local-refine: {e}"))?;
        let mut want = brute::range_discords(&t, m, r);
        want.sort_by_key(|d| d.idx);
        let wi: Vec<usize> = want.iter().map(|d| d.idx).collect();
        for (label, got) in [("yankov", &gy), ("local-refine", &gl)] {
            let gi: Vec<usize> = got.iter().map(|d| d.idx).collect();
            if gi != wi {
                return Err(format!(
                    "n={n} m={m} r={r:.3} segn={segn} parts={parts}: {label} {gi:?} vs brute {wi:?}"
                ));
            }
            for (g, w) in got.iter().zip(&want) {
                if !close(g.nn_dist, w.nn_dist, 1e-4) {
                    return Err(format!(
                        "{label} nnDist at {}: {} vs {}",
                        g.idx, g.nn_dist, w.nn_dist
                    ));
                }
            }
        }
        if ml.exchanged > my.exchanged {
            return Err(format!(
                "n={n} m={m} parts={parts}: local-refine exchanged {} > yankov {}",
                ml.exchanged, my.exchanged
            ));
        }
        Ok(())
    });
}

/// Segmentation covers every window exactly once.
#[test]
fn prop_segmentation_partition() {
    check("segmentation-partition", Config { cases: 50, ..Default::default() }, |rng| {
        let nwin = rng.int_in(1, 5000);
        let segn = rng.int_in(1, 600);
        let seg = Segmentation::new(nwin, segn);
        let mut covered = vec![0u8; nwin];
        for s in 0..seg.nseg {
            for i in seg.seg_range(s) {
                covered[i] += 1;
                if seg.segment_of(i) != s {
                    return Err(format!("segment_of({i}) != {s}"));
                }
            }
        }
        if covered.iter().any(|&c| c != 1) {
            return Err(format!("nwin={nwin} segn={segn}: not a partition"));
        }
        Ok(())
    });
}

/// Bitmap any_in_range agrees with a naive scan for random operations.
#[test]
fn prop_bitmap_matches_naive() {
    use palmad::core::bitmap::Bitmap;
    check("bitmap-naive", Config { cases: 40, ..Default::default() }, |rng| {
        let len = rng.int_in(1, 400);
        let mut bm = Bitmap::ones(len);
        let mut naive = vec![true; len];
        for _ in 0..rng.int_in(0, 3 * len) {
            let i = rng.below(len);
            let v = rng.chance(0.4);
            bm.set(i, v);
            naive[i] = v;
        }
        if bm.count() != naive.iter().filter(|&&b| b).count() {
            return Err("count mismatch".into());
        }
        for _ in 0..20 {
            let a = rng.below(len + 1);
            let b = rng.below(len + 2);
            let got = bm.any_in_range(a, b);
            let want = naive[a.min(len)..b.min(len).max(a.min(len))].iter().any(|&x| x);
            if got != want {
                return Err(format!("any_in_range({a},{b}): {got} vs {want}"));
            }
        }
        let set_bits: Vec<usize> = bm.iter_set().collect();
        let naive_bits: Vec<usize> =
            naive.iter().enumerate().filter(|(_, &b)| b).map(|(i, _)| i).collect();
        if set_bits != naive_bits {
            return Err("iter_set mismatch".into());
        }
        Ok(())
    });
}

/// Eq. 9 padding always yields enough full segments (paper's invariant).
#[test]
fn prop_eq9_padding() {
    use palmad::coordinator::segmentation::pad_len;
    check("eq9-padding", Config { cases: 60, ..Default::default() }, |rng| {
        let m = rng.int_in(3, 100);
        let seglen = m + rng.int_in(1, 200);
        let n = seglen + rng.int_in(1, 10_000);
        let pad = pad_len(n, m, seglen);
        let segn = seglen - m + 1;
        let nwin = n - m + 1;
        let nseg = nwin.div_ceil(segn);
        let padded_nwin = n + pad - m + 1;
        if padded_nwin < nseg * segn {
            return Err(format!("n={n} m={m} seglen={seglen}: pad {pad} too small"));
        }
        if pad < m - 1 {
            return Err(format!("pad {pad} < m-1"));
        }
        Ok(())
    });
}

/// Top-k selection: results are sorted, non-overlapping, and dominated by
/// no excluded candidate.
#[test]
fn prop_topk_dominance() {
    use palmad::core::topk::{top_k_non_overlapping, Scored};
    check("topk-dominance", Config { cases: 40, ..Default::default() }, |rng| {
        let n = rng.int_in(1, 120);
        let m = rng.int_in(1, 20);
        let k = rng.int_in(0, 8);
        let items: Vec<Scored> = (0..n)
            .map(|_| Scored { idx: rng.below(1000), nn_dist: rng.range(0.0, 10.0) })
            .collect();
        let picked = top_k_non_overlapping(&items, m, k);
        // Sorted descending.
        for w in picked.windows(2) {
            if w[0].nn_dist < w[1].nn_dist {
                return Err("not sorted".into());
            }
        }
        // Non-overlapping.
        for a in 0..picked.len() {
            for b in a + 1..picked.len() {
                if picked[a].idx.abs_diff(picked[b].idx) < m {
                    return Err("overlap".into());
                }
            }
        }
        // Every unpicked item is either overlapped by a better pick or
        // k was reached.
        if k > 0 && picked.len() < k {
            for it in &items {
                let excluded = picked.iter().any(|p| {
                    p.idx.abs_diff(it.idx) < m
                });
                if !excluded {
                    return Err(format!("item {it:?} unexplainedly dropped"));
                }
            }
        }
        Ok(())
    });
}

/// A planted flat plateau never crashes discovery and never yields a
/// discord with non-finite distance (the FLAT_EPS semantics).
#[test]
fn prop_flat_plateaus_safe() {
    check("flat-safe", Config { cases: 20, ..Default::default() }, |rng| {
        let n = rng.int_in(100, 300);
        let t = SeriesGen::WithPlateau.generate(n, rng);
        let m = rng.int_in(4, 20);
        let r = rng.range(0.1, 0.8) * max_ed(m);
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        let engine = NativeEngine::with_segn(32);
        let mut metrics = DragMetrics::default();
        let found = pd3(&engine, &view, r, &Pd3Config::default(), &mut metrics)
            .map_err(|e| format!("{e}"))?;
        for d in &found {
            if !d.nn_dist.is_finite() || d.nn_dist < 0.0 {
                return Err(format!("bad discord {d:?}"));
            }
        }
        Ok(())
    });
}

/// Determinism: the same seed-built workload gives identical results
/// across thread counts AND tile kernels (the kernels are bit-identical
/// by construction, so a 1-thread scalar run and a 4-thread lane run
/// must agree exactly).
#[test]
fn prop_thread_determinism() {
    use palmad::engines::TileKernel;
    check("thread-determinism", Config { cases: 8, ..Default::default() }, |rng| {
        let t = SeriesGen::Walk.generate(400, rng);
        let m = 16;
        let r = 0.4 * max_ed(m);
        let stats = RollingStats::compute(&t, m);
        let view = SeriesView { t: &t, stats: &stats };
        let mut results = Vec::new();
        for (threads, kernel) in [
            (1usize, TileKernel::Scalar),
            (4, TileKernel::Scalar),
            (1, TileKernel::Lanes4),
            (4, TileKernel::Lanes4),
        ] {
            let engine = NativeEngine::new(palmad::engines::native::NativeConfig {
                segn: 32,
                threads,
                kernel,
                ..Default::default()
            });
            let mut metrics = DragMetrics::default();
            let mut found = pd3(&engine, &view, r, &Pd3Config::default(), &mut metrics)
                .map_err(|e| format!("{e}"))?;
            found.sort_by_key(|d| d.idx);
            results.push(found);
        }
        for other in &results[1..] {
            if results[0].len() != other.len() {
                return Err("different survivor counts across threads/kernels".into());
            }
            for (a, b) in results[0].iter().zip(other) {
                if a.idx != b.idx || (a.nn_dist - b.nn_dist).abs() > 1e-12 {
                    return Err(format!("{a:?} vs {b:?}"));
                }
            }
        }
        Ok(())
    });
}

/// The scratch-arena tile kernel — recycled output blocks, per-worker
/// scratch, QT seed cache including its cross-length `m -> m+1` advance —
/// matches the brute-force distance oracle on random walks at every step
/// of a length sweep.
#[test]
fn prop_scratch_tile_kernel_matches_oracle() {
    use palmad::engines::{Engine, TileTask};
    use palmad::runtime::types::TileOutputs;

    /// Brute-force tile oracle (direct z-normalized distances).
    fn oracle(t: &[f64], task: TileTask, segn: usize, m: usize, r2: f64) -> TileOutputs {
        let nwin = t.len() - m + 1;
        let mut out = TileOutputs::sized(segn);
        for i in 0..segn {
            let a = task.seg_start + i;
            if a >= nwin {
                continue;
            }
            for j in 0..segn {
                let b = task.chunk_start + j;
                if b >= nwin || a.abs_diff(b) < m {
                    continue;
                }
                let d = ed2norm(&t[a..a + m], &t[b..b + m]);
                out.row_min[i] = out.row_min[i].min(d);
                out.col_min[j] = out.col_min[j].min(d);
                if d < r2 {
                    out.row_kill[i] = true;
                    out.col_kill[j] = true;
                }
            }
        }
        out
    }

    check("scratch-tile-oracle", Config { cases: 12, ..Default::default() }, |rng| {
        let n = rng.int_in(150, 400);
        let t = SeriesGen::Walk.generate(n, rng);
        let m0 = rng.int_in(4, 24);
        let steps = rng.int_in(1, 5);
        let segn = rng.int_in(8, 48);
        let nwin0 = n - m0 + 1;
        let r2 = rng.range(0.5, 2.0 * m0 as f64);
        // Either tile kernel can be on duty — the oracle bound is
        // kernel-independent (and the kernels themselves are bit-equal,
        // pinned separately by the conformance suite).
        let kernel = if rng.chance(0.5) {
            palmad::engines::TileKernel::Scalar
        } else {
            palmad::engines::TileKernel::Lanes4
        };
        let engine = NativeEngine::new(palmad::engines::native::NativeConfig {
            segn,
            kernel,
            ..Default::default()
        });
        let mut tasks = vec![TileTask { seg_start: 0, chunk_start: 0 }]; // self tile
        for _ in 0..3 {
            tasks.push(TileTask { seg_start: rng.below(nwin0), chunk_start: rng.below(nwin0) });
        }
        let mut stats = RollingStats::compute(&t, m0);
        let mut buf: Vec<TileOutputs> = Vec::new();
        for step in 0..=steps {
            let m = m0 + step;
            let view = SeriesView { t: &t, stats: &stats };
            engine.prepare_series(&view);
            engine
                .compute_tiles_into(&view, r2, &tasks, &mut buf)
                .map_err(|e| format!("{e}"))?;
            for (task, got) in tasks.iter().zip(&buf) {
                let want = oracle(&t, *task, segn, m, r2);
                for k in 0..segn {
                    for (side, g, w) in [
                        ("row", got.row_min[k], want.row_min[k]),
                        ("col", got.col_min[k], want.col_min[k]),
                    ] {
                        if g.is_finite() != w.is_finite() {
                            return Err(format!(
                                "m={m} {task:?} {side} {k}: finiteness {g} vs {w}"
                            ));
                        }
                        if w.is_finite() && !close(g, w, 1e-6) {
                            return Err(format!("m={m} {task:?} {side} {k}: {g} vs {w}"));
                        }
                    }
                    // Kill flags are only checked away from the r2
                    // boundary: the qt-form and direct-form distances
                    // legitimately round to different sides within eps.
                    let margin = 1e-9 * (1.0 + r2);
                    if want.row_min[k].is_finite()
                        && (want.row_min[k] - r2).abs() > margin
                        && got.row_kill[k] != want.row_kill[k]
                    {
                        return Err(format!("m={m} {task:?} row_kill {k}"));
                    }
                    if want.col_min[k].is_finite()
                        && (want.col_min[k] - r2).abs() > margin
                        && got.col_kill[k] != want.col_kill[k]
                    {
                        return Err(format!("m={m} {task:?} col_kill {k}"));
                    }
                }
            }
            if step < steps {
                stats.advance(&t);
            }
        }
        Ok(())
    });
}

/// The bulk seed-prefetch sweep is invisible to results: an engine that
/// prefetches between lengths (`Engine::prefetch_length`) produces
/// bit-identical tile outputs to one that advances its seed rows lazily
/// per tile, across a full `min_l..=max_l` sweep *including a mid-sweep
/// series re-bind*, and both stay within the oracle tolerance of fresh
/// (cache-less) evaluation.  Prefetch must also never change the miss
/// count — it only converts lazy advances into bulk ones.
#[test]
fn prop_bulk_prefetch_matches_lazy_sweep() {
    use palmad::engines::{Engine, TileTask};
    use palmad::runtime::types::TileOutputs;

    check("seed-prefetch-sweep", Config { cases: 10, ..Default::default() }, |rng| {
        let n = rng.int_in(200, 400);
        let t1 = SeriesGen::Walk.generate(n, rng);
        let t2 = SeriesGen::Walk.generate(n, rng);
        let m0 = rng.int_in(5, 18);
        let steps = rng.int_in(2, 6);
        let rebind_at = rng.int_in(1, steps);
        let segn = rng.int_in(8, 40);
        let nwin_last = n - (m0 + steps) + 1;
        let r2 = rng.range(0.5, 2.0 * m0 as f64);
        let lazy = NativeEngine::with_segn(segn);
        let bulk = NativeEngine::with_segn(segn);
        // Distinct keys only: a duplicated key inside one concurrent
        // batch legitimately races its own cache row, which would make
        // hit/miss counts (and advance-vs-fresh rounding) scheduling-
        // dependent on both engines.
        let mut tasks = vec![TileTask { seg_start: 0, chunk_start: 0 }];
        while tasks.len() < 4 {
            let cand = TileTask {
                seg_start: rng.below(nwin_last),
                chunk_start: rng.below(nwin_last),
            };
            if !tasks.contains(&cand) {
                tasks.push(cand);
            }
        }
        let mut lbuf: Vec<TileOutputs> = Vec::new();
        let mut bbuf: Vec<TileOutputs> = Vec::new();
        for step in 0..=steps {
            let m = m0 + step;
            let t = if step >= rebind_at { &t2 } else { &t1 };
            let stats = RollingStats::compute(t, m);
            let view = SeriesView { t, stats: &stats };
            lazy.prepare_series(&view);
            bulk.prepare_series(&view);
            lazy.compute_tiles_into(&view, r2, &tasks, &mut lbuf)
                .map_err(|e| format!("{e}"))?;
            bulk.compute_tiles_into(&view, r2, &tasks, &mut bbuf)
                .map_err(|e| format!("{e}"))?;
            for (k, (a, b)) in lbuf.iter().zip(&bbuf).enumerate() {
                if a.row_min != b.row_min
                    || a.col_min != b.col_min
                    || a.row_kill != b.row_kill
                    || a.col_kill != b.col_kill
                {
                    return Err(format!(
                        "m={m} step={step} task {k}: prefetched engine diverged bit-wise"
                    ));
                }
            }
            for (k, task) in tasks.iter().enumerate() {
                let fresh = palmad::engines::native::compute_tile(&view, segn, r2, *task);
                for i in 0..segn {
                    for (side, g, w) in [
                        ("row", bbuf[k].row_min[i], fresh.row_min[i]),
                        ("col", bbuf[k].col_min[i], fresh.col_min[i]),
                    ] {
                        if g.is_finite() != w.is_finite() {
                            return Err(format!(
                                "m={m} task {k} {side} {i}: finiteness {g} vs {w}"
                            ));
                        }
                        if w.is_finite() && !close(g, w, 1e-6) {
                            return Err(format!("m={m} task {k} {side} {i}: {g} vs {w}"));
                        }
                    }
                }
            }
            if step < steps {
                bulk.prefetch_length(t, m + 1);
            }
        }
        let (cl, cb) = (lazy.perf_counters(), bulk.perf_counters());
        if cl.seed_misses != cb.seed_misses {
            return Err(format!(
                "prefetch changed the miss count: lazy {} vs bulk {}",
                cl.seed_misses, cb.seed_misses
            ));
        }
        if cb.seed_prefetched == 0 {
            return Err("sweep never prefetched a row".into());
        }
        if cb.seed_advances != 0 {
            return Err(format!("bulk engine still advanced {} rows lazily", cb.seed_advances));
        }
        Ok(())
    });
}

/// Rng sanity: uniform in range, below() in bounds (meta-test of the
/// substrate the properties rely on).
#[test]
fn prop_rng_bounds() {
    check("rng-bounds", Config { cases: 20, ..Default::default() }, |rng| {
        let lo = rng.range(-100.0, 0.0);
        let hi = lo + rng.range(0.1, 100.0);
        let mut inner = Rng::seed(rng.next_u64());
        for _ in 0..100 {
            let v = inner.range(lo, hi);
            if !(lo..hi).contains(&v) {
                return Err(format!("range({lo},{hi}) gave {v}"));
            }
        }
        Ok(())
    });
}
