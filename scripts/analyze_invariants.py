#!/usr/bin/env python3
"""Hot-path dataflow analysis — toolchain-free mirror of `palmad-analyze`.

This is a line-for-line semantic mirror of `rust/src/util/analyze.rs`
(the canonical implementation, run by `scripts/ci.sh --analyze` when
cargo is available).  Like `lint_invariants.py` it exists so the gate
runs on machines with no Rust toolchain: rules, designated-file lists,
and the annotation grammar here must match the Rust module exactly, and
`--self-test` runs the same fixtures as the Rust unit tests.

Unlike the PR-7 line lint, this analyzer reconstructs per-function
scopes (brace-aware over comment/string-blanked code) and runs three
passes over designated modules (full grammar in ANALYSIS.md):

P1 panic-freedom — in functions marked hot (a `// hot-path: <why>`
   header comment the analyzer discovers), every implicit panic site
   must be justified:

  p1-index    slice/array indexing `recv[..]` needs a `// panic-free:`
              note within 12 lines, unless `recv` is a fixed-extent
              array declared in the same function (param `&[T; N]` or
              `let x = [init; n]` / `let x: [T; N]`)
  p1-unwrap   `.unwrap()` / `.expect(` need a note
  p1-div      `/` or `%` needs a note unless a float literal sits on
              either side (float division cannot panic) or the divisor
              is a nonzero integer literal
  p1-assert   `assert!`-family needs a note (`debug_assert!` is exempt:
              compiled out of release kernels)
  p1-panic    `panic!` / `unreachable!` / `todo!` / `unimplemented!`
              need a note

P2 numeric determinism — in result-bearing modules (core/, engines/,
   coordinator/), FP op order and iteration order must be pinned:

  p2-hash-iter    iterating a HashMap/HashSet-typed binding needs a
                  `// order:` note unless the same function sorts
                  afterwards (`.sort*` on a later line)
  p2-fma          `mul_add` contracts rounding; needs a `// order:`
  p2-float-reduce `.sum(` / `.product(` / `.fold(` in a function that
                  touches a pool needs a `// order:` note
  p2-float-cast   `as f32` narrows; needs a `// order:` note

P3 result discipline — everywhere in rust/src:

  p3-let-drop    `let _ = ...` needs an `// ok-drop:` reason within
                 4 lines (or handle the value)
  p3-ok-discard  statement-position `....ok();` needs an `// ok-drop:`

Cross-cutting:

  note-grammar   a `hot-path:` / `panic-free:` / `order:` / `ok-drop:`
                 marker with no reason text after the colon is rejected
  hot-coverage   each file in HOT_FILES must mark at least one
                 function hot (so deleting markers can't silently
                 disarm P1)

Test modules are exempt from every rule; rust/tests/ and examples/ are
not scanned at all (P1–P3 are library-code discipline).
"""

import os
import re
import sys

SCAN_ROOTS = ("rust/src",)
HOT_FILES = (
    "rust/src/core/distance.rs",
    "rust/src/core/stats.rs",
    "rust/src/engines/scratch.rs",
    "rust/src/util/pool.rs",
)
DETERMINISM_PREFIXES = (
    "rust/src/core/",
    "rust/src/engines/",
    "rust/src/coordinator/",
)
PANIC_WINDOW = 12
ORDER_WINDOW = 8
OKDROP_WINDOW = 4

FN_RE = re.compile(r"\bfn\s+([A-Za-z_][A-Za-z0-9_]*)")
INDEX_RE = re.compile(r"([A-Za-z_][A-Za-z0-9_]*)\[|[\)\]]\[")
FIXED_PARAM_RE = re.compile(
    r"([A-Za-z_][A-Za-z0-9_]*)\s*:\s*&(?:mut\s+)?\[[^\[\];]*;[^\[\]]*\]"
)
FIXED_LET_RE = re.compile(
    r"\blet\s+(?:mut\s+)?([A-Za-z_][A-Za-z0-9_]*)"
    r"(?:\s*:\s*\[[^\[\];]*;[^\[\]]*\])?\s*=\s*\["
)
UNWRAP_RE = re.compile(r"\.\s*(unwrap\s*\(|expect\s*\()")
ASSERT_RE = re.compile(r"(?<!debug_)\b(assert|assert_eq|assert_ne)!\s*[(\[]")
PANIC_RE = re.compile(r"\b(panic|unreachable|todo|unimplemented)!")
HASH_DECL_RE = re.compile(
    r"([A-Za-z_][A-Za-z0-9_]*)\s*:\s*&?(?:mut\s+)?(?:[A-Za-z0-9_]+::)*Hash(?:Map|Set)\b"
)
HASH_LET_RE = re.compile(
    r"\blet\s+(?:mut\s+)?([A-Za-z_][A-Za-z0-9_]*)"
    r"(?:\s*:\s*[^=;]*)?=\s*(?:[A-Za-z0-9_]+::)*Hash(?:Map|Set)\b"
)
HASH_ITER_RE = re.compile(
    r"([A-Za-z_][A-Za-z0-9_]*)\s*\.\s*"
    r"(iter|iter_mut|values|values_mut|keys|drain|retain|into_iter)\s*\("
)
FOR_IN_RE = re.compile(
    r"\bfor\s+.+?\bin\s+&?(?:mut\s+)?([A-Za-z_][A-Za-z0-9_.]*)"
)
FMA_RE = re.compile(r"\.\s*mul_add\s*\(")
REDUCE_RE = re.compile(r"\.\s*(sum|product|fold)\s*[:(<]")
F32_CAST_RE = re.compile(r"\bas\s+f32\b")
LET_DROP_RE = re.compile(r"\blet\s+_\s*=")
NOTE_RE = re.compile(r"(hot-path|panic-free|order|ok-drop):\s*(\S?)")
SORT_RE = re.compile(r"\.\s*sort(_unstable)?(_by|_by_key|_unstable_by_key)?\s*\(")
POOL_RE = re.compile(r"\b[Pp]ool\b")
FLOAT_LEFT_RE = re.compile(r"(\d\.\d*|\.\d+|\bf(32|64))$")
FLOAT_RIGHT_RE = re.compile(r"(\d+\.|\.\d+|\d+(_?f(32|64))\b)")
INT_LIT_RIGHT_RE = re.compile(r"[1-9][0-9_]*")


def strip_rust(text):
    """Split source into (code_lines, comment_lines).

    Identical state machine to lint_invariants.py: code_lines blanks
    comments and string/char-literal contents (quotes kept); each
    line's comment text lands in comment_lines.
    """
    code, comment = [], []
    cur_code, cur_comment = [], []
    i, n = 0, len(text)
    state = "normal"  # normal | line | block | str | rawstr
    depth = 0
    raw_hashes = 0

    def endline():
        code.append("".join(cur_code))
        comment.append("".join(cur_comment))
        cur_code.clear()
        cur_comment.clear()

    while i < n:
        c = text[i]
        if c == "\n":
            if state == "line":
                state = "normal"
            endline()
            i += 1
            continue
        if state == "line":
            cur_comment.append(c)
            i += 1
        elif state == "block":
            if text.startswith("/*", i):
                depth += 1
                cur_comment.append("/*")
                i += 2
            elif text.startswith("*/", i):
                depth -= 1
                cur_comment.append("*/")
                i += 2
                if depth == 0:
                    state = "normal"
            else:
                cur_comment.append(c)
                i += 1
        elif state == "str":
            if c == "\\":
                i += 2
            elif c == '"':
                cur_code.append('"')
                state = "normal"
                i += 1
            else:
                i += 1
        elif state == "rawstr":
            if c == '"' and text[i + 1 : i + 1 + raw_hashes] == "#" * raw_hashes:
                cur_code.append('"')
                state = "normal"
                i += 1 + raw_hashes
            else:
                i += 1
        else:  # normal
            if text.startswith("//", i):
                state = "line"
                cur_comment.append("//")
                i += 2
            elif text.startswith("/*", i):
                state = "block"
                depth = 1
                cur_comment.append("/*")
                i += 2
            elif c == '"':
                cur_code.append('"')
                state = "str"
                i += 1
            elif re.match(r'(?:b?r)(#*)"', text[i : i + 8]):
                m = re.match(r'(?:b?r)(#*)"', text[i : i + 8])
                raw_hashes = len(m.group(1))
                cur_code.append('r"')
                state = "rawstr"
                i += m.end()
            elif c == "'":
                m = re.match(r"'(\\[^']+|[^'\\])'", text[i:])
                if m:
                    cur_code.append("''")  # char literal, contents blanked
                    i += m.end()
                else:
                    cur_code.append(c)  # lifetime tick
                    i += 1
            else:
                cur_code.append(c)
                i += 1
    endline()
    return code, comment


def test_region_start(code_lines):
    """First line of the `#[cfg(test)] mod tests` tail, or len(lines)."""
    for i, line in enumerate(code_lines):
        if re.match(r"\s*#\[cfg\(test\)\]\s*$", line):
            for j in range(i + 1, min(i + 4, len(code_lines))):
                if re.match(r"\s*(pub\s+)?mod\s+tests\b", code_lines[j]):
                    return i
    return len(code_lines)


def has_comment(comment_lines, upto, window, needles):
    lo = max(0, upto - window)
    for line in comment_lines[lo : upto + 1]:
        if any(n in line for n in needles):
            return True
    return False


class Fn:
    """One reconstructed function scope."""

    def __init__(self, name, header):
        self.name = name
        self.header = header  # line index of the `fn` keyword
        self.open = header  # line index of the body `{`
        self.close = None  # line index of the matching `}`
        self.hot = False
        self.fixed = set()  # fixed-extent array bindings
        self.pooled = False  # body mentions a pool


def reconstruct_functions(code, comment):
    """Brace-aware scope reconstruction.

    Returns (fns, line_fn) where line_fn[i] is the index into fns of
    the innermost function covering line i, or -1.  A function spans
    its header line through the line of its closing brace.
    """
    fns = []
    stack = []  # indices of open fns
    open_depths = []
    pending = None  # (name, header_line) awaiting its body `{`
    pend_nest = 0  # () / [] nesting inside the pending signature
    depth = 0
    for i, line in enumerate(code):
        starts = {m.start(): m.group(1) for m in FN_RE.finditer(line)}
        for j, c in enumerate(line):
            if j in starts and pending is None:
                pending = (starts[j], i)
                pend_nest = 0
            if pending is not None and c in "([":
                pend_nest += 1
            elif pending is not None and c in ")]":
                pend_nest -= 1
            elif c == ";" and pending is not None and pend_nest == 0:
                pending = None  # trait declaration, no body
            elif c == "{":
                if pending is not None:
                    f = Fn(pending[0], pending[1])
                    f.open = i
                    fns.append(f)
                    stack.append(len(fns) - 1)
                    open_depths.append(depth)
                    pending = None
                depth += 1
            elif c == "}":
                depth -= 1
                if stack and open_depths[-1] == depth:
                    fns[stack[-1]].close = i
                    stack.pop()
                    open_depths.pop()
    for f in fns:
        if f.close is None:
            f.close = len(code) - 1
    line_fn = [-1] * len(code)
    for idx, f in enumerate(fns):  # later fns are inner: innermost wins
        for i in range(f.header, f.close + 1):
            line_fn[i] = idx
    for f in fns:
        # Hot marker: in the contiguous comment/attribute block directly
        # above the header, or trailing on the header line itself.
        if "hot-path:" in comment[f.header]:
            f.hot = True
        k = f.header - 1
        while k >= 0:
            has_code = code[k].strip() != "" and not code[k].lstrip().startswith("#[")
            if comment[k].strip() == "" and has_code:
                break
            if comment[k].strip() == "" and code[k].strip() == "":
                break  # blank line ends the attached block
            if has_code and comment[k].strip() == "":
                break
            if "hot-path:" in comment[k]:
                f.hot = True
            if has_code:
                break  # trailing comment on a code line: last one taken
            k -= 1
        body = code[f.header : f.close + 1]
        for bl in body:
            for m in FIXED_PARAM_RE.finditer(bl):
                f.fixed.add(m.group(1))
            for m in FIXED_LET_RE.finditer(bl):
                f.fixed.add(m.group(1))
            if POOL_RE.search(bl):
                f.pooled = True
    return fns, line_fn


def hash_bindings(code):
    """File-level set of identifiers declared as HashMap/HashSet."""
    out = set()
    for line in code:
        for m in HASH_DECL_RE.finditer(line):
            out.add(m.group(1))
        for m in HASH_LET_RE.finditer(line):
            out.add(m.group(1))
    return out


def div_exempt(line, pos):
    """True if the `/` or `%` at pos cannot panic: float division
    (float literal adjacent) or a nonzero integer-literal divisor."""
    left = line[:pos].rstrip()
    right = line[pos + 1 :].lstrip()
    if FLOAT_LEFT_RE.search(left):
        return True
    if FLOAT_RIGHT_RE.match(right):
        return True
    if INT_LIT_RIGHT_RE.match(right):
        return True
    return False


def sorts_later(code, fro, upto):
    """True if any code line in (fro, upto] calls a .sort* method."""
    for j in range(fro, upto + 1):
        if SORT_RE.search(code[j]):
            return True
    return False


def scan_file(relpath, text):
    """Analyze one file; returns a list of 'path:line: [rule] msg'."""
    out = []
    code, comment = strip_rust(text)
    relpath = relpath.replace(os.sep, "/")
    tests_at = test_region_start(code)
    fns, line_fn = reconstruct_functions(code, comment)
    hashes = hash_bindings(code[:tests_at])
    determinism = relpath.startswith(DETERMINISM_PREFIXES)

    if relpath in HOT_FILES and not any(
        f.hot and f.header < tests_at for f in fns
    ):
        out.append(
            "%s:1: [hot-coverage] file is on the hot-path list but marks "
            "no function with a `hot-path:` header" % relpath
        )

    for i, line in enumerate(code):
        lineno = i + 1
        if i >= tests_at:
            break

        # note-grammar: every marker needs reason text after the colon.
        for m in NOTE_RE.finditer(comment[i]):
            if not m.group(2):
                out.append(
                    "%s:%d: [note-grammar] `%s:` marker with no reason text"
                    % (relpath, lineno, m.group(1))
                )

        f = fns[line_fn[i]] if line_fn[i] >= 0 else None

        # --- P1: panic-freedom in hot functions -----------------------
        if f is not None and f.hot:
            pf = has_comment(comment, i, PANIC_WINDOW, ("panic-free:",))
            for m in INDEX_RE.finditer(line):
                recv = m.group(1)
                if recv is not None and recv in f.fixed:
                    continue
                if not pf:
                    out.append(
                        "%s:%d: [p1-index] indexing `%s[..]` in hot fn `%s` "
                        "without a fixed-extent binding or `// panic-free:` "
                        "note" % (relpath, lineno, recv or "?", f.name)
                    )
                break  # one report per line
            if UNWRAP_RE.search(line) and not pf:
                out.append(
                    "%s:%d: [p1-unwrap] unwrap/expect in hot fn `%s` without "
                    "a `// panic-free:` note" % (relpath, lineno, f.name)
                )
            for m in re.finditer(r"[/%]", line):
                if not div_exempt(line, m.start()) and not pf:
                    out.append(
                        "%s:%d: [p1-div] non-literal `/` or `%%` in hot fn "
                        "`%s` without a `// panic-free:` note"
                        % (relpath, lineno, f.name)
                    )
                    break
            if ASSERT_RE.search(line) and not pf:
                out.append(
                    "%s:%d: [p1-assert] assert! in hot fn `%s` without a "
                    "`// panic-free:` note (debug_assert! is exempt)"
                    % (relpath, lineno, f.name)
                )
            if PANIC_RE.search(line) and not pf:
                out.append(
                    "%s:%d: [p1-panic] explicit panic path in hot fn `%s` "
                    "without a `// panic-free:` note" % (relpath, lineno, f.name)
                )

        # --- P2: numeric determinism in result-bearing modules --------
        if determinism and f is not None:
            od = has_comment(comment, i, ORDER_WINDOW, ("order:",))
            hit = None
            for m in HASH_ITER_RE.finditer(line):
                if m.group(1) in hashes:
                    hit = m.group(1)
                    break
            if hit is None:
                fm = FOR_IN_RE.search(line)
                if fm and fm.group(1).split(".")[-1] in hashes:
                    hit = fm.group(1)
            if hit is not None and not od and not sorts_later(code, i, f.close):
                out.append(
                    "%s:%d: [p2-hash-iter] iteration over hash-ordered `%s` "
                    "in `%s` with no later sort and no `// order:` note"
                    % (relpath, lineno, hit, f.name)
                )
            if FMA_RE.search(line) and not od:
                out.append(
                    "%s:%d: [p2-fma] mul_add contracts rounding; needs an "
                    "`// order:` note" % (relpath, lineno)
                )
            if f.pooled and REDUCE_RE.search(line) and not od:
                out.append(
                    "%s:%d: [p2-float-reduce] reduction in pool-adjacent fn "
                    "`%s` needs an `// order:` note" % (relpath, lineno, f.name)
                )
            if F32_CAST_RE.search(line) and not od:
                out.append(
                    "%s:%d: [p2-float-cast] `as f32` narrows; needs an "
                    "`// order:` note" % (relpath, lineno)
                )

        # --- P3: result discipline ------------------------------------
        okd = has_comment(comment, i, OKDROP_WINDOW, ("ok-drop:",))
        if LET_DROP_RE.search(line) and not okd:
            out.append(
                "%s:%d: [p3-let-drop] `let _ =` without an `// ok-drop:` "
                "reason (handle the value or justify the drop)"
                % (relpath, lineno)
            )
        stripped = line.strip()
        if (
            ".ok();" in stripped
            and "=" not in stripped
            and "return" not in stripped
            and not okd
        ):
            out.append(
                "%s:%d: [p3-ok-discard] statement-position `.ok();` without "
                "an `// ok-drop:` reason" % (relpath, lineno)
            )
    return out


def run(root):
    violations = []
    for scan_root in SCAN_ROOTS:
        top = os.path.join(root, scan_root)
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames.sort()
            for fn in sorted(filenames):
                if not fn.endswith(".rs"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root)
                with open(path) as f:
                    violations.extend(scan_file(rel, f.read()))
    return violations


# --- self-test fixtures: keep in lockstep with the unit tests in
# --- rust/src/util/analyze.rs (same inputs, same expected rule ids).
HOT = "// hot-path: fixture kernel.\n"
FIXTURES = [
    # P1: the seeded violation — an unguarded index in a hot-path fn.
    ("rust/src/core/x.rs", HOT + "fn f(t: &[f64], i: usize) -> f64 { t[i] }\n", ["p1-index"]),
    (
        "rust/src/core/x.rs",
        HOT + "fn f(t: &[f64], i: usize) -> f64 {\n"
        "    // panic-free: caller guarantees i < t.len().\n    t[i]\n}\n",
        [],
    ),
    (
        "rust/src/core/x.rs",
        HOT + "fn f(c: &mut [f64; 4]) { c[0] = 1.0; }\n",
        [],
    ),
    (
        "rust/src/core/x.rs",
        HOT + "fn f() -> f64 {\n    let acc = [0.0f64; 4];\n    acc[3]\n}\n",
        [],
    ),
    # P1 applies only to hot-marked functions.
    ("rust/src/core/x.rs", "fn f(t: &[f64], i: usize) -> f64 { t[i] }\n", []),
    (
        "rust/src/core/x.rs",
        HOT + "fn f(r: Option<u8>) -> u8 { r.unwrap() }\n",
        ["p1-unwrap"],
    ),
    (
        "rust/src/core/x.rs",
        HOT + "fn f(r: Option<u8>) -> u8 {\n"
        '    // panic-free: seeded by caller, always Some.\n    r.expect("seeded")\n}\n',
        [],
    ),
    ("rust/src/core/x.rs", HOT + "fn f(a: u64, b: u64) -> u64 { a / b }\n", ["p1-div"]),
    ("rust/src/core/x.rs", HOT + "fn f(a: usize) -> usize { a / 4 }\n", []),
    ("rust/src/core/x.rs", HOT + "fn f(s: f64) -> f64 { 1.0 / s }\n", []),
    (
        "rust/src/core/x.rs",
        HOT + "fn f(m: usize) { assert!(m >= 2); }\n",
        ["p1-assert"],
    ),
    ("rust/src/core/x.rs", HOT + "fn f(m: usize) { debug_assert!(m >= 2); }\n", []),
    (
        "rust/src/core/x.rs",
        HOT + 'fn f() { panic!("boom"); }\n',
        ["p1-panic"],
    ),
    # note-grammar: a marker with no reason text is itself rejected.
    (
        "rust/src/core/x.rs",
        "// hot-path:\nfn f() {}\n",
        ["note-grammar"],
    ),
    # hot-coverage: hot-listed files must mark at least one function.
    ("rust/src/core/stats.rs", "fn f() {}\n", ["hot-coverage"]),
    # P2: the seeded violation — a HashMap-order-dependent result.
    (
        "rust/src/engines/x.rs",
        "fn f(m: &HashMap<u64, f64>, out: &mut Vec<f64>) {\n"
        "    for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n}\n",
        ["p2-hash-iter"],
    ),
    (
        "rust/src/engines/x.rs",
        "fn f(m: &HashMap<u64, f64>, out: &mut Vec<f64>) {\n"
        "    for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n"
        "    out.sort_unstable_by(|a, b| a.total_cmp(b));\n}\n",
        [],
    ),
    (
        "rust/src/engines/x.rs",
        "fn f(m: &HashMap<u64, f64>, out: &mut Vec<f64>) {\n"
        "    // order: gauge aggregation; result is order-insensitive.\n"
        "    for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n}\n",
        [],
    ),
    (
        "rust/src/engines/x.rs",
        "fn f(m: &BTreeMap<u64, f64>, out: &mut Vec<f64>) {\n"
        "    for (_k, v) in m.iter() {\n        out.push(*v);\n    }\n}\n",
        [],
    ),
    (
        "rust/src/core/x.rs",
        "fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }\n",
        ["p2-fma"],
    ),
    (
        "rust/src/core/x.rs",
        "// order: fused once, never mixed with the unfused path.\n"
        "fn f(a: f64, b: f64, c: f64) -> f64 { a.mul_add(b, c) }\n",
        [],
    ),
    (
        "rust/src/core/x.rs",
        "fn f(pool: &RoundPool, xs: &[f64]) -> f64 { xs.iter().sum() }\n",
        ["p2-float-reduce"],
    ),
    ("rust/src/core/x.rs", "fn f(xs: &[f64]) -> f64 { xs.iter().sum() }\n", []),
    ("rust/src/core/x.rs", "fn f(x: f64) -> f32 { x as f32 }\n", ["p2-float-cast"]),
    (
        "rust/src/core/x.rs",
        "// order: narrowed once at export; consumers compare f32 bits.\n"
        "fn f(x: f64) -> f32 { x as f32 }\n",
        [],
    ),
    # P2 is scoped to result-bearing modules.
    ("rust/src/util/x.rs", "fn f(x: f64) -> f32 { x as f32 }\n", []),
    # P3: the seeded violation — a bare `let _ =` on a Result.
    (
        "rust/src/util/x.rs",
        "fn f() { let _ = std::fs::remove_file(\"x\"); }\n",
        ["p3-let-drop"],
    ),
    (
        "rust/src/util/x.rs",
        "fn f() {\n    // ok-drop: best-effort cleanup; missing file is fine.\n"
        "    let _ = std::fs::remove_file(\"x\");\n}\n",
        [],
    ),
    (
        "rust/src/util/x.rs",
        "#[cfg(test)]\nmod tests {\n    fn f() { let _ = std::fs::remove_file(\"x\"); }\n}\n",
        [],
    ),
    (
        "rust/src/util/x.rs",
        "fn f(w: &mut impl Write) { w.flush().ok(); }\n",
        ["p3-ok-discard"],
    ),
    ("rust/src/util/x.rs", "fn f(s: &str) { let x = s.parse::<u8>().ok(); }\n", []),
]


def self_test():
    failed = 0
    for path, text, want in FIXTURES:
        got = [v.split("[")[1].split("]")[0] for v in scan_file(path, text)]
        if got != want:
            failed += 1
            print("fixture FAILED: %s\n  want %s\n  got  %s" % (path, want, got))
            print("  text: %r" % text)
    print("self-test: %d fixtures, %d failed" % (len(FIXTURES), failed))
    return failed


def main(argv):
    if "--self-test" in argv:
        return 1 if self_test() else 0
    root = argv[1] if len(argv) > 1 else "."
    violations = run(root)
    for v in violations:
        print(v)
    print("analyze-invariants: %d violation(s)" % len(violations))
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
