#!/usr/bin/env bash
# CI gate for the rust workspace: formatting, lints, build, tests.
#
#   scripts/ci.sh                # full gate
#   scripts/ci.sh --fast         # skip the release build (debug tests only)
#   scripts/ci.sh --bench-smoke  # additionally smoke-run the microbench
#                                # (PALMAD_BENCH_QUICK=1; catches bench
#                                # bitrot and regenerates BENCH_*.json)
#
# The workspace is fully offline (vendored path deps), so no network is
# needed.  `cargo fmt --check` and `cargo clippy -- -D warnings` keep the
# legacy/new dual pipelines (TilePipeline::Legacy vs Scratch, drain vs
# ring slide) warning-clean; no lint allowlist is needed at the moment —
# add targeted `#[allow]`s in code rather than blanket flags here.
# Benches are NOT timed here — see EXPERIMENTS.md §Perf / §Streaming for
# the perf tracking flow (BENCH_*.json).

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
BENCH_SMOKE=0
for arg in "$@"; do
  case "$arg" in
    --fast) FAST=1 ;;
    --bench-smoke) BENCH_SMOKE=1 ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

if [ "$FAST" -eq 0 ]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

if [ "$BENCH_SMOKE" -eq 1 ]; then
  echo "== microbench smoke (PALMAD_BENCH_QUICK=1) =="
  PALMAD_BENCH_QUICK=1 cargo bench --bench microbench
fi

echo "CI gate passed."
