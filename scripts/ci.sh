#!/usr/bin/env bash
# CI gate for the rust workspace: formatting, lints, build, tests.
#
#   scripts/ci.sh          # full gate
#   scripts/ci.sh --fast   # skip the release build (debug tests only)
#
# The workspace is fully offline (vendored path deps), so no network is
# needed.  Benches are NOT run here — see scripts in EXPERIMENTS.md §Perf
# for the perf tracking flow (BENCH_*.json).

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
[ "${1:-}" = "--fast" ] && FAST=1

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

if [ "$FAST" -eq 0 ]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "CI gate passed."
