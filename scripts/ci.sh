#!/usr/bin/env bash
# CI gate for the rust workspace: formatting, lints, build, tests.
#
#   scripts/ci.sh                # full gate
#   scripts/ci.sh --fast         # skip the release build (debug tests only)
#   scripts/ci.sh --clippy       # lint-only gate: fmt + clippy, then exit.
#                                # Includes the scoped unwrap_used denies
#                                # (src/analysis + src/core/windows.rs carry
#                                # #![cfg_attr(not(test), deny(clippy::unwrap_used))]
#                                # — user-facing analysis paths must not panic
#                                # on NaN/degenerate input).
#   scripts/ci.sh --bench-smoke  # additionally smoke-run the microbench
#                                # (PALMAD_BENCH_QUICK=1; catches bench
#                                # bitrot, regenerates BENCH_*.json, and
#                                # asserts the seed-prefetch sweep counters
#                                # are non-zero and the simd_kernel
#                                # before/after object is emitted)
#   scripts/ci.sh --kernel-matrix
#                                # additionally re-run the kernel
#                                # conformance + allocation suites under
#                                # EVERY tile kernel in KERNEL_NAMES
#                                # (rust/src/engines/mod.rs — extracted
#                                # dynamically, so a new kernel joins
#                                # the matrix automatically; lanes8 is
#                                # skipped with a notice on hosts
#                                # without AVX-512F).  Every engine
#                                # built with default config follows the
#                                # env, so the whole differential harness
#                                # and the zero-allocation proofs gate
#                                # each kernel.
#   scripts/ci.sh --service-smoke
#                                # boot the TCP job service on an
#                                # ephemeral port and drive a scripted
#                                # client session (parse rejections, a
#                                # DATA upload swept end-to-end, a job
#                                # cancelled mid-sweep, METRICS, graceful
#                                # SHUTDOWN), asserting the server exits
#                                # cleanly.  Then run the serving load
#                                # generator (examples/service_loadgen)
#                                # against the evented front end and
#                                # assert BENCH_service.json records a
#                                # non-zero "rejected" count (admission
#                                # control actually pushed back).  Also
#                                # part of the default (non --fast)
#                                # gate, which builds the release
#                                # binary it needs anyway.
#   scripts/ci.sh --chaos        # run the fault-injection / checkpoint
#                                # chaos suite (rust/tests/chaos_faults.rs)
#                                # under every KERNEL_NAMES tile kernel:
#                                # kill-and-resume bit-identity at every
#                                # step boundary, panic isolation,
#                                # transient-error retry, NaN
#                                # contamination, service restart
#                                # auto-resume.  Also part of the default
#                                # (non --fast) gate — crash-safety claims
#                                # are gated, not aspirational.
#   scripts/ci.sh --lint-invariants
#                                # run ONLY the repo-invariant lint
#                                # (SAFETY comments, transmute/unwrap
#                                # containment, the CONCURRENCY.md atomic
#                                # audit, coordinator lock discipline)
#                                # and exit.  Also part of EVERY gate
#                                # (default and --fast): it is a pure
#                                # source scan, needs no toolchain
#                                # (python fallback), and guards the
#                                # documented invariants directly.
#   scripts/ci.sh --analyze      # run ONLY the hot-path dataflow
#                                # analysis (P1 panic-freedom, P2
#                                # numeric determinism, P3 result
#                                # discipline — see ANALYSIS.md) and
#                                # exit.  Also part of EVERY gate
#                                # (default and --fast), python mirror
#                                # first (toolchain-free), cargo bin as
#                                # the fallback.
#   scripts/ci.sh --no-panic     # link-time panic-freedom proof:
#                                # release-build rust/no_panic_probe,
#                                # where reaching a panic from the
#                                # annotated distance kernels is an
#                                # undefined-symbol link error.  Needs
#                                # cargo; skips with a notice when it
#                                # is absent.
#   scripts/ci.sh --loom         # model-check the concurrency core:
#                                # build with RUSTFLAGS="--cfg palmad_loom"
#                                # (util::loomsync swaps std::sync for the
#                                # vendored checker) and run
#                                # rust/tests/loom_models.rs, which
#                                # exhaustively explores the SliceWriter /
#                                # RoundPool / QtSeedCache / EnginePool /
#                                # Service-shutdown protocols under
#                                # bounded preemptions.  Standalone leg
#                                # (separate build cfg); exits after.
#   scripts/ci.sh --miri         # run the unsafe core (util::pool,
#                                # util::binio, engines::scratch,
#                                # engines::native) under Miri's aliasing
#                                # + UB interpreter.  Needs a nightly
#                                # toolchain with the miri component;
#                                # skips with a notice when absent.
#   scripts/ci.sh --sanitize thread|address
#                                # rebuild std + tests with TSan/ASan
#                                # instrumentation (nightly -Zbuild-std)
#                                # and run the threaded core.  Skips with
#                                # a notice when nightly is absent.
#
# The workspace is fully offline (vendored path deps), so no network is
# needed.  `cargo fmt --check` and `cargo clippy -- -D warnings` keep the
# legacy/new dual pipelines (TilePipeline::Legacy vs Scratch, drain vs
# ring slide) warning-clean; path-scoped lints live as in-source
# attributes (clippy cannot scope lints per path from the CLI) — add
# targeted `#[allow]`s in code rather than blanket flags here.
# Benches are NOT timed here — see EXPERIMENTS.md §Perf / §Streaming /
# §Prefetch for the perf tracking flow (BENCH_*.json).

set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
BENCH_SMOKE=0
CLIPPY_ONLY=0
KERNEL_MATRIX=0
SERVICE_SMOKE=0
CHAOS=0
LINT_ONLY=0
ANALYZE_ONLY=0
NO_PANIC=0
LOOM=0
MIRI=0
SANITIZE=""
EXPECT_SANITIZER=0
for arg in "$@"; do
  if [ "$EXPECT_SANITIZER" -eq 1 ]; then
    SANITIZE="$arg"
    EXPECT_SANITIZER=0
    continue
  fi
  case "$arg" in
    --fast) FAST=1 ;;
    --bench-smoke) BENCH_SMOKE=1 ;;
    --clippy) CLIPPY_ONLY=1 ;;
    --kernel-matrix) KERNEL_MATRIX=1 ;;
    --service-smoke) SERVICE_SMOKE=1 ;;
    --chaos) CHAOS=1 ;;
    --lint-invariants) LINT_ONLY=1 ;;
    --analyze) ANALYZE_ONLY=1 ;;
    --no-panic) NO_PANIC=1 ;;
    --loom) LOOM=1 ;;
    --miri) MIRI=1 ;;
    --sanitize) EXPECT_SANITIZER=1 ;;
    --sanitize=*) SANITIZE="${arg#*=}" ;;
    *) echo "unknown flag: $arg" >&2; exit 2 ;;
  esac
done
if [ "$EXPECT_SANITIZER" -eq 1 ]; then
  echo "--sanitize needs a value: thread|address" >&2
  exit 2
fi
if [ -n "$SANITIZE" ] && [ "$SANITIZE" != thread ] && [ "$SANITIZE" != address ]; then
  echo "unknown sanitizer: $SANITIZE (thread|address)" >&2
  exit 2
fi

# The invariant lint is part of every gate: a pure source scan of the
# documented unsafe/concurrency invariants (CONCURRENCY.md).  The cargo
# binary and scripts/lint_invariants.py implement the same rules over
# the same fixtures; prefer python here so the gate runs before (and
# without) any compilation, falling back to the cargo bin where only a
# Rust toolchain exists.  `cargo test` independently runs the Rust
# implementation over the whole tree (util::lint::tests).
run_lint_invariants() {
  echo "== lint-invariants (unsafe discipline + CONCURRENCY.md audit) =="
  if command -v python3 >/dev/null 2>&1; then
    python3 scripts/lint_invariants.py .
  elif command -v cargo >/dev/null 2>&1; then
    cargo run -q --bin palmad-lint -- .
  else
    echo "lint-invariants: neither python3 nor cargo available" >&2
    exit 1
  fi
}

# The dataflow analysis joins the lint in every gate: same
# dual-implementation scheme (scripts/analyze_invariants.py is the
# toolchain-free mirror of rust/src/util/analyze.rs; `cargo test`
# independently runs the Rust side over the whole tree via
# util::analyze::tests::whole_tree_is_clean).
run_analyze_invariants() {
  echo "== analyze-invariants (hot-path P1/P2/P3 dataflow analysis) =="
  if command -v python3 >/dev/null 2>&1; then
    python3 scripts/analyze_invariants.py .
  elif command -v cargo >/dev/null 2>&1; then
    cargo run -q --bin palmad-analyze -- .
  else
    echo "analyze-invariants: neither python3 nor cargo available" >&2
    exit 1
  fi
}

if [ "$LINT_ONLY" -eq 1 ]; then
  run_lint_invariants
  echo "CI invariant-lint gate passed."
  exit 0
fi

if [ "$ANALYZE_ONLY" -eq 1 ]; then
  run_analyze_invariants
  echo "CI dataflow-analysis gate passed."
  exit 0
fi

if [ "$NO_PANIC" -eq 1 ]; then
  if ! command -v cargo >/dev/null 2>&1; then
    echo "no-panic: cargo unavailable — skipping link-time proof (notice, not failure)"
    exit 0
  fi
  echo "== no-panic probe (link-time proof over the distance kernels) =="
  # A surviving panic path in any probed kernel is an undefined-symbol
  # link error (PANIC_REACHABLE_IN_<kernel>); see rust/no_panic_probe.
  (cd rust/no_panic_probe && cargo build --release)
  echo "no-panic: all probed kernels link panic-free."
  exit 0
fi

if [ "$LOOM" -eq 1 ]; then
  if ! command -v cargo >/dev/null 2>&1; then
    echo "loom: cargo unavailable — skipping model checking (notice, not failure)"
    exit 0
  fi
  echo "== loom model checking (RUSTFLAGS=--cfg palmad_loom) =="
  # Release: the checker replays thousands of schedules per model.  Only
  # the loom_models target is built/run under this cfg — the rest of the
  # suite uses std primitives that would panic outside loom::model.
  RUSTFLAGS="${RUSTFLAGS:-} --cfg palmad_loom" cargo test -q --release --test loom_models
  echo "loom: all models passed."
  exit 0
fi

if [ "$MIRI" -eq 1 ]; then
  if ! cargo +nightly miri --version >/dev/null 2>&1; then
    echo "miri: nightly toolchain with miri component unavailable — skipping (notice, not failure)"
    exit 0
  fi
  echo "== miri (unsafe core: pool, binio codec, scratch, native) =="
  # -Zmiri-disable-isolation: the pool/engine tests read env knobs and
  # the clock.  Scaled-down #[cfg(miri)] profiles keep this tractable;
  # expect minutes, not seconds.
  MIRIFLAGS="${MIRIFLAGS:-} -Zmiri-disable-isolation" \
    cargo +nightly miri test -q --lib -- \
    util::pool util::binio engines::scratch engines::native
  echo "miri: unsafe core clean."
  exit 0
fi

if [ -n "$SANITIZE" ]; then
  if ! cargo +nightly --version >/dev/null 2>&1; then
    echo "sanitize: nightly toolchain unavailable — skipping (notice, not failure)"
    exit 0
  fi
  HOST=$(rustc +nightly -vV | sed -n 's/^host: //p')
  echo "== ${SANITIZE} sanitizer (nightly, -Zbuild-std, $HOST) =="
  # std must be instrumented too (TSan especially), hence -Zbuild-std.
  # Scope: the threaded core (lib unit tests) + the service integration
  # suite, where cross-thread handoffs actually happen.
  RUSTFLAGS="${RUSTFLAGS:-} -Zsanitizer=$SANITIZE" \
    cargo +nightly test -q -Zbuild-std --target "$HOST" \
    --lib --test integration_service --test chaos_faults
  echo "sanitize($SANITIZE): clean."
  exit 0
fi

# Tile kernels for the matrix/chaos legs, extracted from the single
# source of truth (pub const KERNEL_NAMES in rust/src/engines/mod.rs —
# kept on one line exactly so this sed stays trivial).  `auto` is
# deliberately absent there: it resolves to a listed kernel.  lanes8 is
# *correct* on any host (safe Rust) but only fast with AVX-512F; gate
# hosts without the feature skip that leg with a notice rather than
# spend the wall time.
kernel_list() {
  names=$(sed -n 's/^pub const KERNEL_NAMES:.*&\[\(.*\)\];.*$/\1/p' rust/src/engines/mod.rs \
    | tr -d '",')
  if [ -z "$names" ]; then
    echo "kernel matrix: KERNEL_NAMES not found in rust/src/engines/mod.rs (single-line const expected)" >&2
    exit 1
  fi
  out=""
  for k in $names; do
    if [ "$k" = lanes8 ] && ! grep -q avx512f /proc/cpuinfo 2>/dev/null; then
      echo "kernel matrix: host lacks AVX-512F — skipping the lanes8 leg" >&2
      continue
    fi
    out="$out $k"
  done
  echo "$out"
}

run_lint_invariants
run_analyze_invariants

echo "== cargo fmt --check =="
cargo fmt --all --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

if [ "$CLIPPY_ONLY" -eq 1 ]; then
  echo "CI lint gate passed."
  exit 0
fi

if [ "$FAST" -eq 0 ]; then
  echo "== cargo build --release =="
  cargo build --release
  # The service smoke rides the default gate: the release binary is
  # already built, the scripted client is one small example on top.
  SERVICE_SMOKE=1
  # So does the chaos suite: robustness regressions (checkpoint drift,
  # a panic taking down a worker) must not land silently.
  CHAOS=1
fi

echo "== cargo test -q =="
cargo test -q

if [ "$SERVICE_SMOKE" -eq 1 ]; then
  echo "== service smoke (ephemeral port, scripted client) =="
  cargo build --release --bin palmad --example service_client
  SMOKE_LOG=$(mktemp)
  target/release/palmad serve --addr 127.0.0.1:0 --workers 2 >"$SMOKE_LOG" 2>&1 &
  SERVER_PID=$!
  ADDR=""
  for _ in $(seq 1 100); do
    ADDR=$(grep -m1 '^LISTENING ' "$SMOKE_LOG" | cut -d' ' -f2 || true)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
      echo "service smoke: server died before listening" >&2
      cat "$SMOKE_LOG" >&2
      exit 1
    fi
    sleep 0.1
  done
  if [ -z "$ADDR" ]; then
    echo "service smoke: no LISTENING line from the server" >&2
    cat "$SMOKE_LOG" >&2
    kill "$SERVER_PID" 2>/dev/null || true
    exit 1
  fi
  if ! target/release/examples/service_client "$ADDR"; then
    echo "service smoke: scripted client session failed" >&2
    cat "$SMOKE_LOG" >&2
    kill "$SERVER_PID" 2>/dev/null || true
    exit 1
  fi
  # The client ends with SHUTDOWN: the server must drain and exit 0 on
  # its own (no kill).
  if ! wait "$SERVER_PID"; then
    echo "service smoke: server did not shut down cleanly" >&2
    cat "$SMOKE_LOG" >&2
    exit 1
  fi
  rm -f "$SMOKE_LOG"
  echo "service smoke: clean shutdown"

  # Second leg: the admission/fairness load generator.  It boots its own
  # in-process service (round-robin baseline, then weighted-fair), drives
  # the evented front end over real sockets, and writes BENCH_service.json.
  # The admission burst must actually trip the bounded queue: a zero
  # "rejected" count means ERR BUSY back-pressure silently stopped firing.
  echo "== service loadgen (admission + weighted fairness) =="
  cargo build --release --example service_loadgen
  target/release/examples/service_loadgen BENCH_service.json
  # `|| true`: a missing key must reach the diagnostic below, not let
  # pipefail+set -e kill the script silently at this assignment.
  rej=$(grep -o '"rejected": *[0-9]*' BENCH_service.json | tail -n1 | grep -o '[0-9]*$' || true)
  if [ -z "${rej:-}" ] || [ "$rej" -eq 0 ]; then
    echo "service loadgen: \"rejected\" missing or zero in BENCH_service.json — admission control did not reject under burst" >&2
    exit 1
  fi
  echo "service loadgen: admission rejected $rej submits under burst"
fi

if [ "$KERNEL_MATRIX" -eq 1 ]; then
  # The conformance + allocation suites under each tile kernel.  The
  # env flips every default-config engine (NativeConfig::default reads
  # PALMAD_TILE_KERNEL), while the conformance tests additionally pin
  # explicit oracle-vs-lane pairs regardless of the env.
  for k in $(kernel_list); do
    echo "== kernel matrix ($k): conformance + alloc steady state =="
    PALMAD_TILE_KERNEL=$k cargo test -q --test kernel_conformance --test alloc_steady_state
  done
fi

if [ "$CHAOS" -eq 1 ]; then
  # Checkpoint/resume bit-identity is a per-kernel claim (the seed rows
  # carried through a checkpoint replay that kernel's exact rounding —
  # and lanes4f32 exports none at all, so its resume must re-seed
  # bit-identically), so the chaos suite runs under every tile kernel
  # like the conformance matrix does.
  for k in $(kernel_list); do
    echo "== chaos suite ($k): fault injection + checkpoint/resume =="
    PALMAD_TILE_KERNEL=$k cargo test -q --test chaos_faults
  done
fi

if [ "$BENCH_SMOKE" -eq 1 ]; then
  echo "== microbench smoke (PALMAD_BENCH_QUICK=1) =="
  PALMAD_BENCH_QUICK=1 cargo bench --bench microbench
  # The bulk seed-prefetch sweep must actually run: a zero or missing
  # counter in the artifact means the path silently degraded to lazy
  # per-row advances.
  # `|| true`: a missing key must reach the diagnostic below, not let
  # pipefail+set -e kill the script silently at this assignment.
  rows=$(grep -o '"prefetched_rows":[0-9]*' BENCH_native_tile.json | head -n1 | cut -d: -f2 || true)
  if [ -z "${rows:-}" ] || [ "$rows" -eq 0 ]; then
    echo "bench smoke: prefetched_rows missing or zero in BENCH_native_tile.json" >&2
    exit 1
  fi
  echo "bench smoke: seed_prefetch advanced $rows rows"
  # The lane-vs-scalar before/after must be in the artifact: a missing
  # object means the kernel bench silently fell off the emit path.
  if ! grep -q '"simd_kernel"' BENCH_native_tile.json; then
    echo "bench smoke: simd_kernel object missing from BENCH_native_tile.json" >&2
    exit 1
  fi
  # Any lane width is fine; only its absence means the object lost its
  # shape.
  if ! grep -q '"lanes":[0-9]' BENCH_native_tile.json; then
    echo "bench smoke: simd_kernel lane width missing from BENCH_native_tile.json" >&2
    exit 1
  fi
  # The width/precision variants must be measured too: lanes8 (AVX-512
  # width at f64) and lanes4f32 (the tolerance-banded f32 kernel), plus
  # the dispatcher's resolution, all live in the same object.
  for key in '"lanes8"' '"lanes4f32"' '"auto_resolves_to"'; do
    if ! grep -q "$key" BENCH_native_tile.json; then
      echo "bench smoke: simd_kernel $key entry missing from BENCH_native_tile.json" >&2
      exit 1
    fi
  done
  echo "bench smoke: simd_kernel before/after emitted (scalar/lanes4/lanes8/lanes4f32)"
fi

echo "CI gate passed."
