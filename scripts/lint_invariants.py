#!/usr/bin/env python3
"""Repo-invariant lint — toolchain-free mirror of `palmad-lint`.

This is a line-for-line semantic mirror of `rust/src/util/lint.rs` (the
canonical implementation, run by `scripts/ci.sh --lint-invariants` when
cargo is available).  It exists so the invariant gate runs on machines
with no Rust toolchain: the rules, allowlists, and CONCURRENCY.md table
grammar here must match the Rust module exactly, and `--self-test` runs
the same fixtures as the Rust unit tests to keep the two honest.

Rules (see CONCURRENCY.md "Invariants enforced by palmad-lint"):

  safety-comment      every `unsafe` is preceded (<= 12 lines) by
                      `// SAFETY:` or a `# Safety` doc section
  transmute-allowlist `transmute` only in rust/src/util/pool.rs
  atomic-audited      every atomic op in non-test src code has a
                      CONCURRENCY.md row or an inline `// ordering:`
                      comment (<= 8 lines above)
  atomic-ordering     an op's Ordering must be listed in its row
  relaxed-publication Relaxed is forbidden on rows marked
                      publication = yes (site and table self-check)
  coordinator-lock    no direct `.lock()` in rust/src/coordinator
                      (use util::sync::{lock_recover, wait_recover})
  unwrap-allowlist    no `.unwrap()` in non-test src code outside
                      allowlisted files (`expect("...")` is fine)

Test modules, rust/tests/, and examples/ are exempt from the atomic,
lock, and unwrap rules; safety/transmute apply everywhere scanned.
vendor/ is not scanned.
"""

import os
import re
import sys

SCAN_ROOTS = ("rust/src", "rust/tests", "examples")
TRANSMUTE_ALLOWLIST = {"rust/src/util/pool.rs"}
UNWRAP_ALLOWLIST = {"rust/src/util/pool.rs"}
SAFETY_WINDOW = 12
ORDERING_WINDOW = 8

ATOMIC_METHODS = (
    "load|store|swap|fetch_add|fetch_sub|fetch_and|fetch_or|fetch_xor"
    "|fetch_max|fetch_min|fetch_update|compare_exchange_weak|compare_exchange"
)
ATOMIC_RE = re.compile(
    r"(?:([A-Za-z_][A-Za-z0-9_]*)\s*(?:\[[^\]]*\])?\s*)?\.\s*(%s)\s*\(" % ATOMIC_METHODS
)
ORDERING_RE = re.compile(r"Ordering::([A-Za-z]+)")
UNSAFE_RE = re.compile(r"\bunsafe\b")
TRANSMUTE_RE = re.compile(r"\btransmute\b")
TRAILING_RECV_RE = re.compile(r"([A-Za-z_][A-Za-z0-9_]*)\s*(?:\[[^\]]*\])?\s*$")


def strip_rust(text):
    """Split source into (code_lines, comment_lines).

    code_lines blanks out comments and string/char-literal contents
    (quotes kept) so token rules never fire on prose; comment_lines
    holds each line's comment text for SAFETY / ordering detection.
    """
    code, comment = [], []
    cur_code, cur_comment = [], []
    i, n = 0, len(text)
    state = "normal"  # normal | line | block | str | rawstr
    depth = 0
    raw_hashes = 0

    def endline():
        code.append("".join(cur_code))
        comment.append("".join(cur_comment))
        cur_code.clear()
        cur_comment.clear()

    while i < n:
        c = text[i]
        if c == "\n":
            if state == "line":
                state = "normal"
            endline()
            i += 1
            continue
        if state == "line":
            cur_comment.append(c)
            i += 1
        elif state == "block":
            if text.startswith("/*", i):
                depth += 1
                cur_comment.append("/*")
                i += 2
            elif text.startswith("*/", i):
                depth -= 1
                cur_comment.append("*/")
                i += 2
                if depth == 0:
                    state = "normal"
            else:
                cur_comment.append(c)
                i += 1
        elif state == "str":
            if c == "\\":
                i += 2
            elif c == '"':
                cur_code.append('"')
                state = "normal"
                i += 1
            else:
                i += 1
        elif state == "rawstr":
            if c == '"' and text[i + 1 : i + 1 + raw_hashes] == "#" * raw_hashes:
                cur_code.append('"')
                state = "normal"
                i += 1 + raw_hashes
            else:
                i += 1
        else:  # normal
            if text.startswith("//", i):
                state = "line"
                cur_comment.append("//")
                i += 2
            elif text.startswith("/*", i):
                state = "block"
                depth = 1
                cur_comment.append("/*")
                i += 2
            elif c == '"':
                cur_code.append('"')
                state = "str"
                i += 1
            elif re.match(r'(?:b?r)(#*)"', text[i : i + 8]):
                m = re.match(r'(?:b?r)(#*)"', text[i : i + 8])
                raw_hashes = len(m.group(1))
                cur_code.append('r"')
                state = "rawstr"
                i += m.end()
            elif c == "'":
                m = re.match(r"'(\\[^']+|[^'\\])'", text[i:])
                if m:
                    cur_code.append("''")  # char literal, contents blanked
                    i += m.end()
                else:
                    cur_code.append(c)  # lifetime tick
                    i += 1
            else:
                cur_code.append(c)
                i += 1
    endline()
    return code, comment


def test_region_start(code_lines):
    """First line of the `#[cfg(test)] mod tests` tail, or len(lines)."""
    for i, line in enumerate(code_lines):
        if re.match(r"\s*#\[cfg\(test\)\]\s*$", line):
            for j in range(i + 1, min(i + 4, len(code_lines))):
                if re.match(r"\s*(pub\s+)?mod\s+tests\b", code_lines[j]):
                    return i
    return len(code_lines)


def parse_audit_table(md_text):
    """CONCURRENCY.md rows -> {(file, atomic_name): (orderings, publication)}."""
    rows = {}
    errors = []
    for lineno, line in enumerate(md_text.splitlines(), 1):
        line = line.strip()
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if len(cells) < 6 or cells[0] in ("File", "") or set(cells[0]) <= {"-", " "}:
            continue
        path, names, _ops, orderings, publication, _why = cells[:6]
        pub = publication.lower().startswith("yes")
        ords = set(re.findall(r"[A-Za-z]+", orderings))
        if pub and "Relaxed" in ords:
            errors.append(
                "CONCURRENCY.md:%d: [relaxed-publication] row '%s' is "
                "publication=yes but lists Relaxed" % (lineno, names)
            )
        for name in names.split(","):
            rows[(path, name.strip())] = (ords, pub)
    return rows, errors


def has_comment(comment_lines, upto, window, needles):
    lo = max(0, upto - window)
    for line in comment_lines[lo : upto + 1]:
        if any(n in line for n in needles):
            return True
    return False


def scan_file(relpath, text, table):
    """Lint one file; returns a list of 'path:line: [rule] msg' strings."""
    out = []
    code, comment = strip_rust(text)
    relpath = relpath.replace(os.sep, "/")
    is_test_file = relpath.startswith("rust/tests/") or relpath.startswith("examples/")
    tests_at = 0 if is_test_file else test_region_start(code)
    in_coordinator = relpath.startswith("rust/src/coordinator/")

    for i, line in enumerate(code):
        lineno = i + 1
        in_test = is_test_file or i >= tests_at

        if UNSAFE_RE.search(line) and not has_comment(
            comment, i, SAFETY_WINDOW, ("SAFETY:", "# Safety")
        ):
            out.append(
                "%s:%d: [safety-comment] `unsafe` without a // SAFETY: "
                "comment (or /// # Safety section) in the preceding %d lines"
                % (relpath, lineno, SAFETY_WINDOW)
            )

        if TRANSMUTE_RE.search(line) and relpath not in TRANSMUTE_ALLOWLIST:
            out.append(
                "%s:%d: [transmute-allowlist] transmute outside %s"
                % (relpath, lineno, sorted(TRANSMUTE_ALLOWLIST))
            )

        if in_test:
            continue

        if in_coordinator and ".lock()" in line:
            out.append(
                "%s:%d: [coordinator-lock] direct .lock() in coordinator/ "
                "(use util::sync::{lock_recover, wait_recover})" % (relpath, lineno)
            )

        if ".unwrap()" in line and relpath not in UNWRAP_ALLOWLIST:
            out.append(
                "%s:%d: [unwrap-allowlist] .unwrap() outside allowlisted "
                'files (use expect("...") with the invariant)' % (relpath, lineno)
            )

        for m in ATOMIC_RE.finditer(line):
            window = " ".join(code[i : i + 4])
            # Scan only the call's own argument list: from its opening
            # paren to the balanced close (so a neighbouring statement's
            # Ordering:: cannot bleed into this site's audit).
            open_at = m.end() - 1  # the regex ends at the opening paren
            args, depth_p = [], 0
            for ch in window[open_at:]:
                args.append(ch)
                depth_p += (ch == "(") - (ch == ")")
                if depth_p == 0:
                    break
            # Only calls passing Ordering:: are atomic ops (filters
            # Vec::swap, slice::swap, non-atomic .store/.load methods).
            ords = set(ORDERING_RE.findall("".join(args)))
            if not ords:
                continue
            recv = m.group(1)
            if recv is None:
                for back in range(i - 1, max(0, i - 3) - 1, -1):
                    t = TRAILING_RECV_RE.search(code[back].rstrip())
                    if t:
                        recv = t.group(1)
                        break
            row = table.get((relpath, recv)) if recv else None
            if row is not None:
                allowed, publication = row
                for o in ords:
                    if o not in allowed:
                        out.append(
                            "%s:%d: [atomic-ordering] %s.%s uses Ordering::%s, "
                            "not listed in its CONCURRENCY.md row"
                            % (relpath, lineno, recv, m.group(2), o)
                        )
                if publication and "Relaxed" in ords:
                    out.append(
                        "%s:%d: [relaxed-publication] Relaxed on publication "
                        "flag `%s`" % (relpath, lineno, recv)
                    )
            elif not has_comment(comment, i, ORDERING_WINDOW, ("ordering:",)):
                out.append(
                    "%s:%d: [atomic-audited] atomic op on `%s` has no "
                    "CONCURRENCY.md row and no inline `// ordering:` comment"
                    % (relpath, lineno, recv or "?")
                )
    return out


def run(root):
    with open(os.path.join(root, "CONCURRENCY.md")) as f:
        table, errors = parse_audit_table(f.read())
    violations = list(errors)
    for scan_root in SCAN_ROOTS:
        top = os.path.join(root, scan_root)
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames.sort()
            for fn in sorted(filenames):
                if not fn.endswith(".rs"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root)
                with open(path) as f:
                    violations.extend(scan_file(rel, f.read(), table))
    return violations


# --- self-test fixtures: keep in lockstep with the unit tests in
# --- rust/src/util/lint.rs (same inputs, same expected rule hits).
FIXTURES = [
    ("rust/src/x.rs", "fn f() { unsafe { g(); } }\n", ["safety-comment"]),
    ("rust/src/x.rs", "// SAFETY: g has no preconditions.\nfn f() { unsafe { g(); } }\n", []),
    ("rust/src/x.rs", 'fn f() { let s = "unsafe transmute"; }\n', []),
    ("rust/src/x.rs", "fn f() { core::mem::transmute::<u8, i8>(0) }\n", ["transmute-allowlist"]),
    ("rust/src/util/pool.rs", "// SAFETY: ok.\nunsafe { transmute::<u8, i8>(0) }\n", []),
    (
        "rust/src/coordinator/x.rs",
        "fn f(m: &Mutex<u8>) { let _ = m.lock(); }\n",
        ["coordinator-lock"],
    ),
    (
        "rust/src/coordinator/x.rs",
        "#[cfg(test)]\nmod tests {\n  fn f(m: &Mutex<u8>) { let _ = m.lock(); }\n}\n",
        [],
    ),
    ("rust/src/x.rs", "fn f() { None::<u8>.unwrap(); }\n", ["unwrap-allowlist"]),
    ("examples/x.rs", "fn f() { None::<u8>.unwrap(); }\n", []),
    ("rust/src/x.rs", "fn f(a: &A) { a.flag.store(true, Ordering::SeqCst); }\n", ["atomic-audited"]),
    (
        "rust/src/x.rs",
        "fn f(a: &A) {\n  // ordering: SeqCst because fixture.\n"
        "  a.flag.store(true, Ordering::SeqCst);\n}\n",
        [],
    ),
    ("rust/src/x.rs", "fn f(v: &mut Vec<u8>) { v.swap(0, 1); }\n", []),
    (
        "rust/src/audited.rs",
        "fn f(a: &A) { a.good.store(true, Ordering::Release); }\n",
        [],
    ),
    (
        "rust/src/audited.rs",
        "fn f(a: &A) { a.good.store(true, Ordering::Relaxed); }\n",
        ["atomic-ordering", "relaxed-publication"],
    ),
    (
        "rust/src/x.rs",
        "fn f(v: &mut Vec<u8>, a: &A) {\n    v.swap(0, 1);\n"
        "    a.flag.store(true, Ordering::SeqCst);\n}\n",
        ["atomic-audited"],
    ),
    (
        "rust/src/x.rs",
        "fn f(a: &A) {\n    a.counters.really_long_name\n"
        "        .fetch_add(1, Ordering::Relaxed);\n}\n",
        ["atomic-audited"],
    ),
]
FIXTURE_TABLE_MD = "| rust/src/audited.rs | good | store | Release | yes | fixture |\n"


def self_test():
    table, errs = parse_audit_table(FIXTURE_TABLE_MD)
    assert not errs, errs
    failed = 0
    for path, text, want in FIXTURES:
        got = [v.split("[")[1].split("]")[0] for v in scan_file(path, text, table)]
        if got != want:
            failed += 1
            print("fixture FAILED: %s\n  want %s\n  got  %s" % (path, want, got))
    bad_row = "| rust/src/y.rs | f | store | Relaxed | yes | bad |\n"
    if not parse_audit_table(bad_row)[1]:
        failed += 1
        print("fixture FAILED: publication=yes + Relaxed row not rejected")
    print("self-test: %d fixtures, %d failed" % (len(FIXTURES) + 1, failed))
    return failed


def main(argv):
    if "--self-test" in argv:
        return 1 if self_test() else 0
    root = argv[1] if len(argv) > 1 else "."
    violations = run(root)
    for v in violations:
        print(v)
    print("lint-invariants: %d violation(s)" % len(violations))
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
