//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The workspace must build with no crates.io access, so this vendored
//! shim provides exactly the surface the repo uses:
//!
//! - [`Error`] / [`Result`] (message-carrying, `Send + Sync`)
//! - the [`anyhow!`], [`bail!`] and [`ensure!`] macros
//! - the [`Context`] extension trait on `Result` and `Option`
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent, which in turn
//! makes `?` work on any standard error type.

use std::fmt;

/// A message-carrying error. Context layers are joined as
/// `"outer: inner"` (the shim keeps one flattened string rather than a
/// source chain — enough for log/CLI output).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("not an int")?;
        ensure!(v >= 0, "negative: {v}");
        Ok(v)
    }

    #[test]
    fn question_mark_and_context() {
        assert_eq!(parse("4").unwrap(), 4);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not an int: "), "{e}");
        assert_eq!(parse("-1").unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<i32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        let x = 3;
        assert_eq!(anyhow!("got {x}").to_string(), "got 3");
        assert_eq!(anyhow!("got {}", 9).to_string(), "got 9");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }

    #[test]
    fn ensure_without_message() {
        fn f(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(f(true).is_ok());
        assert!(f(false).unwrap_err().to_string().contains("condition failed"));
    }
}
