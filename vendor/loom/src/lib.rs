//! Vendored minimal model checker exposing a `loom`-compatible API subset.
//!
//! The real `loom` crate cannot be vendored here (offline build), so this
//! is a from-scratch reimplementation of the part palmad needs: run a
//! closure under *every* (bounded) thread interleaving of its
//! synchronization operations and fail loudly — with the offending
//! schedule — on assertion failures, deadlocks, and lost wakeups.
//!
//! # How it works
//!
//! Model threads are real OS threads, but at most one ever runs at a
//! time: a global token (the `current` thread id in [`rt::Exec`]) is
//! handed from thread to thread at *switch points* — immediately before
//! every atomic operation, mutex acquisition, condvar notify, spawn and
//! join.  At each switch point with more than one runnable thread the
//! scheduler consults a recorded decision stack: on the first execution
//! it always picks option 0 and records the fan-out; when the closure
//! finishes, the deepest non-exhausted decision is incremented and the
//! whole closure re-runs, replaying the prefix — a depth-first search
//! over schedules.  `Condvar::notify_one` with several waiters is a
//! decision point too (which waiter wakes is part of the schedule).
//!
//! # Soundness and bounds
//!
//! - Execution is *sequentially consistent*: `Ordering` arguments are
//!   accepted and ignored.  Every interleaving explored is a real SC
//!   interleaving, so any failure found is a real bug; relaxed-memory
//!   reorderings beyond SC are **not** explored (that gap is covered by
//!   the written `CONCURRENCY.md` audit, not this checker).
//! - Exploration is bounded by a *preemption budget* (default 2,
//!   overridable via [`model::Builder::max_preemptions`] or
//!   `PALMAD_LOOM_PREEMPTIONS`): schedules that forcibly switch away
//!   from a runnable thread more than the budget allows are pruned.
//!   Within the budget the search is exhaustive, and the CHESS result
//!   applies: almost all concurrency bugs manifest within 2 forced
//!   preemptions.  Voluntary switches (blocking on a mutex/condvar/join)
//!   are free and always fully explored.
//! - Spurious condvar wakeups are not modeled; `std` permits them, so
//!   user code must still use predicate loops (the models assert this
//!   shape by construction).
//!
//! A deadlock — every live thread blocked — aborts the model and panics
//! with the thread states and the schedule that led there.  A lost
//! wakeup therefore shows up as a deadlock, which is exactly how the
//! service-shutdown regression model pins its bug.
//!
//! Mutexes poison on panic exactly like `std` (guards check
//! `std::thread::panicking()` on drop), and `thread::spawn` wraps the
//! child body in `catch_unwind` so a *deliberate* child panic (the
//! poison-recovery models) is reported through `JoinHandle::join` as
//! `Err` instead of tearing down the exploration.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod rt {
    //! The scheduler runtime: global token, decision stack, abort logic.

    use std::cell::Cell;
    use std::sync::{Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard, OnceLock};

    /// Hard cap on model threads; models are meant to be tiny.
    pub const MAX_THREADS: usize = 16;

    #[derive(Clone, Copy, PartialEq, Eq, Debug)]
    pub(crate) enum Run {
        Runnable,
        BlockedMutex(usize),
        BlockedCondvar(usize),
        BlockedJoin(usize),
        /// The main thread waiting for every spawned thread to finish.
        BlockedJoinAll,
        Done,
    }

    #[derive(Clone, Debug, PartialEq, Eq)]
    pub(crate) struct Decision {
        pub options: Vec<usize>,
        pub chosen: usize,
    }

    #[derive(Default)]
    pub(crate) struct Exec {
        pub active: bool,
        pub threads: Vec<Run>,
        pub current: usize,
        pub decisions: Vec<Decision>,
        pub depth: usize,
        pub preemptions: usize,
        pub max_preemptions: usize,
        pub aborting: Option<String>,
    }

    pub(crate) struct Sched {
        pub m: StdMutex<Exec>,
        pub cv: StdCondvar,
    }

    pub(crate) fn sched() -> &'static Sched {
        static S: OnceLock<Sched> = OnceLock::new();
        S.get_or_init(|| Sched { m: StdMutex::new(Exec::default()), cv: StdCondvar::new() })
    }

    thread_local! {
        pub(crate) static TID: Cell<Option<usize>> = const { Cell::new(None) };
    }

    pub(crate) fn cur_tid() -> usize {
        TID.with(|t| t.get()).expect("loom: sync op on a thread that is not part of a model")
    }

    /// Lock the scheduler state, recovering from poison (a panicking
    /// model thread must not wedge the checker itself).
    pub(crate) fn slock() -> StdMutexGuard<'static, Exec> {
        match sched().m.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    pub(crate) fn fmt_schedule(ex: &Exec) -> String {
        let picks: Vec<String> =
            ex.decisions.iter().map(|d| format!("{}/{}", d.chosen, d.options.len())).collect();
        format!("[{}]", picks.join(" "))
    }

    /// Mark the model failed and wake every thread so it can unwind.
    pub(crate) fn abort(ex: &mut Exec, msg: String) {
        if ex.aborting.is_none() {
            ex.aborting = Some(msg);
        }
        sched().cv.notify_all();
    }

    /// Panic out of a model thread after an abort — unless this thread is
    /// already unwinding (a panic inside a panic aborts the process).
    pub(crate) fn abort_panic(msg: &str) {
        if !std::thread::panicking() {
            panic!("loom: model aborted: {msg}");
        }
    }

    /// Pick the next thread to run.  Called with the scheduler locked.
    pub(crate) fn pick_next(ex: &mut Exec) {
        let mut options: Vec<usize> = ex
            .threads
            .iter()
            .enumerate()
            .filter(|(_, r)| **r == Run::Runnable)
            .map(|(i, _)| i)
            .collect();
        if options.is_empty() {
            if ex.threads.iter().all(|r| *r == Run::Done) {
                return; // iteration over; nobody is waiting for the token
            }
            let msg = format!(
                "deadlock: no runnable thread (states: {:?}, schedule: {})",
                ex.threads,
                fmt_schedule(ex)
            );
            abort(ex, msg);
            return;
        }
        let cur_runnable = ex.threads.get(ex.current).is_some_and(|r| *r == Run::Runnable);
        if cur_runnable {
            // Deterministic option order: staying on the current thread is
            // option 0 (never a preemption), then ascending thread id.
            options.retain(|&t| t != ex.current);
            options.insert(0, ex.current);
            if ex.preemptions >= ex.max_preemptions {
                options.truncate(1); // budget exhausted: no forced switch
            }
        }
        let chosen = choose(ex, options);
        if cur_runnable && chosen != ex.current {
            ex.preemptions += 1;
        }
        ex.current = chosen;
        sched().cv.notify_all();
    }

    /// Consume one decision (recording it on first visit).  Single-option
    /// points are free: they record nothing and replay identically.
    pub(crate) fn choose(ex: &mut Exec, options: Vec<usize>) -> usize {
        if options.len() == 1 {
            return options[0];
        }
        let idx = if ex.depth < ex.decisions.len() {
            if ex.decisions[ex.depth].options != options {
                let msg = format!(
                    "nondeterministic model: replay diverged at depth {} (recorded {:?}, got {:?})",
                    ex.depth, ex.decisions[ex.depth].options, options
                );
                abort(ex, msg);
                return options[0];
            }
            ex.decisions[ex.depth].chosen
        } else {
            ex.decisions.push(Decision { options: options.clone(), chosen: 0 });
            0
        };
        ex.depth += 1;
        options[idx]
    }

    /// Block until the token lands on `me` (runnable), or the model
    /// aborts.  Consumes the scheduler guard.
    pub(crate) fn handoff(mut g: StdMutexGuard<'static, Exec>, me: usize) {
        loop {
            if let Some(msg) = g.aborting.clone() {
                drop(g);
                abort_panic(&msg);
                return; // only reachable while unwinding
            }
            if g.current == me && g.threads.get(me) == Some(&Run::Runnable) {
                return;
            }
            g = match sched().cv.wait(g) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// A switch point: offer the scheduler a chance to run someone else.
    /// Every visible operation calls this first.
    pub(crate) fn switch_point() {
        let me = cur_tid();
        let mut g = slock();
        if let Some(msg) = g.aborting.clone() {
            drop(g);
            abort_panic(&msg);
            return;
        }
        if !g.active {
            drop(g);
            if std::thread::panicking() {
                return;
            }
            panic!("loom: sync op outside a model (wrap the code in loom::model)");
        }
        pick_next(&mut g);
        handoff(g, me);
    }

    /// Mark `tid` finished, wake joiners, and pass the token on.  Must
    /// never panic: it runs on the exit path of every model thread.
    pub(crate) fn thread_done(tid: usize) {
        let mut g = slock();
        if g.threads.get(tid).is_none() {
            return;
        }
        g.threads[tid] = Run::Done;
        for r in g.threads.iter_mut() {
            if *r == Run::BlockedJoin(tid) {
                *r = Run::Runnable;
            }
        }
        let others_done = g
            .threads
            .iter()
            .all(|r| matches!(r, Run::Done | Run::BlockedJoinAll));
        if others_done {
            for r in g.threads.iter_mut() {
                if *r == Run::BlockedJoinAll {
                    *r = Run::Runnable;
                }
            }
        }
        if g.aborting.is_none() {
            pick_next(&mut g);
        }
        sched().cv.notify_all();
    }

    /// Main-thread wait for every spawned thread to finish (so an
    /// iteration only ends once all effects are observable).
    pub(crate) fn wait_all_done() {
        let me = cur_tid();
        loop {
            let mut g = slock();
            if let Some(msg) = g.aborting.clone() {
                drop(g);
                abort_panic(&msg);
                return;
            }
            let others_done =
                g.threads.iter().enumerate().all(|(i, r)| i == me || *r == Run::Done);
            if others_done {
                g.threads[me] = Run::Done;
                return;
            }
            g.threads[me] = Run::BlockedJoinAll;
            pick_next(&mut g);
            handoff(g, me);
        }
    }
}

pub mod model {
    //! Model entry points: [`model`] and the tunable [`Builder`].

    use crate::rt::{self, Run};
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

    /// Exploration bounds; fields mirror the knobs of the real loom.
    #[derive(Clone, Debug)]
    pub struct Builder {
        /// Forced-preemption budget per execution (see crate docs).
        pub max_preemptions: usize,
        /// Safety valve: fail the model if exploration exceeds this many
        /// schedules instead of spinning forever.
        pub max_iterations: u64,
        /// Print the schedule count on completion.
        pub log: bool,
    }

    impl Default for Builder {
        fn default() -> Self {
            Self::new()
        }
    }

    fn env_u64(key: &str, default: u64) -> u64 {
        std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    impl Builder {
        pub fn new() -> Self {
            Self {
                max_preemptions: env_u64("PALMAD_LOOM_PREEMPTIONS", 2) as usize,
                max_iterations: env_u64("PALMAD_LOOM_MAX_ITERS", 1_000_000),
                log: std::env::var("PALMAD_LOOM_LOG").is_ok(),
            }
        }

        /// Run `f` under every schedule within the bounds.  Panics (with
        /// the failing schedule on stderr) if any execution panics,
        /// deadlocks, or diverges.
        pub fn check<F: Fn()>(&self, f: F) {
            // The scheduler state is a process-wide singleton, but the
            // test harness runs #[test] fns on several threads: serialize
            // whole models here (recovering the lock if a failing model
            // panicked out while holding it) instead of asserting.
            static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());
            let _serial = SERIAL.lock().unwrap_or_else(|p| p.into_inner());
            {
                let mut g = rt::slock();
                assert!(!g.active, "loom: nested models are not supported");
                *g = rt::Exec {
                    active: true,
                    max_preemptions: self.max_preemptions,
                    ..Default::default()
                };
            }
            let mut iterations = 0u64;
            loop {
                iterations += 1;
                {
                    let mut g = rt::slock();
                    g.threads = vec![Run::Runnable];
                    g.current = 0;
                    g.depth = 0;
                    g.preemptions = 0;
                    g.aborting = None;
                }
                rt::TID.with(|t| t.set(Some(0)));
                let result = catch_unwind(AssertUnwindSafe(|| {
                    f();
                    rt::wait_all_done();
                }));
                rt::TID.with(|t| t.set(None));
                if let Err(e) = result {
                    let schedule = {
                        let mut g = rt::slock();
                        g.active = false;
                        rt::abort(&mut g, "main model thread panicked".to_string());
                        rt::fmt_schedule(&g)
                    };
                    eprintln!(
                        "loom: model FAILED on iteration {iterations}; schedule {schedule}"
                    );
                    resume_unwind(e);
                }
                // Depth-first backtrack: drop exhausted suffix, bump the
                // deepest live decision, replay.
                let exhausted = {
                    let mut g = rt::slock();
                    loop {
                        match g.decisions.last_mut() {
                            None => break true,
                            Some(d) if d.chosen + 1 < d.options.len() => {
                                d.chosen += 1;
                                break false;
                            }
                            Some(_) => {
                                g.decisions.pop();
                            }
                        }
                    }
                };
                if exhausted {
                    break;
                }
                if iterations >= self.max_iterations {
                    let mut g = rt::slock();
                    g.active = false;
                    drop(g);
                    panic!(
                        "loom: model exceeded {} schedules — shrink the model or raise PALMAD_LOOM_MAX_ITERS",
                        self.max_iterations
                    );
                }
            }
            {
                let mut g = rt::slock();
                g.active = false;
            }
            if self.log {
                eprintln!("loom: model complete: {iterations} schedules explored");
            }
        }
    }

    /// Explore `f` under the default bounds.
    pub fn model<F: Fn()>(f: F) {
        Builder::new().check(f)
    }
}

pub use model::model;

pub mod thread {
    //! Model-aware `std::thread` subset.

    use crate::rt::{self, Run};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// Handle to a model thread; `join` blocks *in the model* first, then
    /// reaps the OS thread.
    pub struct JoinHandle<T> {
        tid: usize,
        os: std::thread::JoinHandle<std::thread::Result<T>>,
    }

    #[derive(Default)]
    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        pub fn new() -> Self {
            Self::default()
        }

        pub fn name(mut self, name: String) -> Self {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            rt::switch_point();
            let tid = {
                let mut g = rt::slock();
                let tid = g.threads.len();
                assert!(tid < rt::MAX_THREADS, "loom: model spawned too many threads");
                g.threads.push(Run::Runnable);
                tid
            };
            let os = std::thread::Builder::new()
                .name(self.name.unwrap_or_else(|| format!("loom-{tid}")))
                .spawn(move || {
                    rt::TID.with(|t| t.set(Some(tid)));
                    // Wait to be scheduled for the first time.
                    rt::handoff(rt::slock(), tid);
                    let r = catch_unwind(AssertUnwindSafe(f));
                    rt::thread_done(tid);
                    rt::TID.with(|t| t.set(None));
                    r
                })?;
            Ok(JoinHandle { tid, os })
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("loom: OS thread spawn failed")
    }

    /// Voluntary switch point.
    pub fn yield_now() {
        rt::switch_point();
    }

    impl<T> JoinHandle<T> {
        /// Like `std::thread::JoinHandle::join`: `Err` carries the child's
        /// panic payload (the child body runs under `catch_unwind`).
        pub fn join(self) -> std::thread::Result<T> {
            rt::switch_point();
            loop {
                let mut g = rt::slock();
                if g.aborting.is_some() {
                    // Permissive teardown: the child exits on its own once
                    // the abort broadcast reaches it.
                    drop(g);
                    break;
                }
                if g.threads.get(self.tid) == Some(&Run::Done) {
                    drop(g);
                    break;
                }
                let me = rt::cur_tid();
                g.threads[me] = Run::BlockedJoin(self.tid);
                rt::pick_next(&mut g);
                rt::handoff(g, me);
            }
            match self.os.join() {
                Ok(inner) => inner,
                Err(e) => Err(e),
            }
        }
    }
}

pub mod sync {
    //! Model-aware `std::sync` subset.  `PoisonError`/`LockResult` are
    //! re-exported from `std` so calling code keeps identical signatures.

    pub use std::sync::{Arc, LockResult, PoisonError};

    use crate::rt::{self, Run};
    use std::cell::{Cell, UnsafeCell};
    use std::marker::PhantomData;

    /// Model mutex: non-reentrant, poisoning, blocking is a scheduler
    /// decision.  All bookkeeping fields are only touched while holding
    /// the global scheduler lock (or the token, which is exclusive).
    pub struct Mutex<T> {
        held_by: Cell<Option<usize>>,
        poisoned: Cell<bool>,
        data: UnsafeCell<T>,
    }

    // SAFETY: `held_by`/`poisoned` are only mutated under the global
    // scheduler lock or while holding the execution token (at most one
    // model thread runs at any instant), and `data` is only reachable
    // through a held guard; the scheduler's own std mutex provides the
    // inter-thread happens-before edges.
    unsafe impl<T: Send> Send for Mutex<T> {}
    unsafe impl<T: Send> Sync for Mutex<T> {}

    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        /// Guards are `!Send`, like std's.
        _nosend: PhantomData<*mut ()>,
    }

    impl<T> Mutex<T> {
        pub fn new(t: T) -> Self {
            Self { held_by: Cell::new(None), poisoned: Cell::new(false), data: UnsafeCell::new(t) }
        }

        fn id(&self) -> usize {
            self as *const Self as *const () as usize
        }

        pub fn is_poisoned(&self) -> bool {
            self.poisoned.get()
        }

        pub fn into_inner(self) -> LockResult<T> {
            let poisoned = self.poisoned.get();
            let v = self.data.into_inner();
            if poisoned {
                Err(PoisonError::new(v))
            } else {
                Ok(v)
            }
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            rt::switch_point();
            self.lock_no_switch()
        }

        /// Acquire without the leading switch point (used by
        /// `Condvar::wait` re-acquisition, whose blocking release already
        /// was a scheduling event).
        fn lock_no_switch(&self) -> LockResult<MutexGuard<'_, T>> {
            let me = rt::cur_tid();
            loop {
                let mut g = rt::slock();
                if g.aborting.is_some() {
                    // Permissive teardown so Drop impls can run while
                    // every thread unwinds.
                    self.held_by.set(Some(me));
                    drop(g);
                    break;
                }
                match self.held_by.get() {
                    None => {
                        self.held_by.set(Some(me));
                        drop(g);
                        break;
                    }
                    Some(owner) if owner == me => {
                        let msg = format!(
                            "self-deadlock: thread {me} re-locking a mutex it holds (schedule {})",
                            rt::fmt_schedule(&g)
                        );
                        rt::abort(&mut g, msg.clone());
                        drop(g);
                        rt::abort_panic(&msg);
                        break;
                    }
                    Some(_) => {
                        g.threads[me] = Run::BlockedMutex(self.id());
                        rt::pick_next(&mut g);
                        rt::handoff(g, me);
                        // Woken because the holder released; re-contend.
                    }
                }
            }
            let guard = MutexGuard { lock: self, _nosend: PhantomData };
            if self.poisoned.get() {
                Err(PoisonError::new(guard))
            } else {
                Ok(guard)
            }
        }

        /// Release and wake contenders.  Never panics (runs in Drop).
        fn unlock_from_guard(&self) {
            let mut g = rt::slock();
            self.held_by.set(None);
            let id = self.id();
            for r in g.threads.iter_mut() {
                if *r == Run::BlockedMutex(id) {
                    *r = Run::Runnable;
                }
            }
            drop(g);
            rt::sched().cv.notify_all();
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            // SAFETY: the guard proves exclusive ownership of the lock.
            unsafe { &*self.lock.data.get() }
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            // SAFETY: the guard proves exclusive ownership of the lock.
            unsafe { &mut *self.lock.data.get() }
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            if std::thread::panicking() {
                self.lock.poisoned.set(true);
            }
            self.lock.unlock_from_guard();
        }
    }

    /// Mirror of `std::sync::WaitTimeoutResult` for the model's
    /// always-times-out [`Condvar::wait_timeout`].
    #[derive(Clone, Copy, Debug)]
    pub struct WaitTimeoutResult(pub(crate) bool);

    impl WaitTimeoutResult {
        pub fn timed_out(&self) -> bool {
            self.0
        }
    }

    /// Model condvar.  `notify_one` with several waiters is a scheduling
    /// decision; there are no spurious wakeups.
    #[derive(Default)]
    pub struct Condvar {
        _private: (),
    }

    impl Condvar {
        pub fn new() -> Self {
            Self { _private: () }
        }

        fn id(&self) -> usize {
            self as *const Self as usize
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            let me = rt::cur_tid();
            let lock: &'a Mutex<T> = guard.lock;
            {
                let mut g = rt::slock();
                if let Some(msg) = g.aborting.clone() {
                    drop(g);
                    drop(guard);
                    rt::abort_panic(&msg);
                    // Unreachable unless already unwinding, where the
                    // (poisoned) guard re-acquire below is permissive.
                    return lock.lock_no_switch();
                }
                // Atomically release the lock and start waiting: both
                // transitions happen under the one scheduler lock, so no
                // notify can slip between them.
                lock.held_by.set(None);
                let mid = lock.id();
                for r in g.threads.iter_mut() {
                    if *r == Run::BlockedMutex(mid) {
                        *r = Run::Runnable;
                    }
                }
                std::mem::forget(guard); // released manually above
                g.threads[me] = Run::BlockedCondvar(self.id());
                rt::pick_next(&mut g);
                rt::handoff(g, me);
            }
            // Notified (no spurious wakeups): re-acquire.
            lock.lock_no_switch()
        }

        /// Model `wait_timeout`: a timed wait can always time out, so
        /// the model treats the timeout as firing immediately — the
        /// lock is released, every other thread gets a scheduling turn,
        /// and the call returns with `timed_out() == true` without ever
        /// entering a blocked state.  This over-approximates std (which
        /// may instead wake via an earlier notify): any protocol that
        /// re-checks its predicate after a timed wait — the only sound
        /// way to use one — is explored faithfully, and a thread parked
        /// in `wait_timeout` can never contribute to a model deadlock.
        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            _dur: std::time::Duration,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            let lock: &'a Mutex<T> = guard.lock;
            drop(guard); // releases the lock and wakes contenders
            rt::switch_point();
            match lock.lock_no_switch() {
                Ok(g) => Ok((g, WaitTimeoutResult(true))),
                Err(p) => {
                    Err(PoisonError::new((p.into_inner(), WaitTimeoutResult(true))))
                }
            }
        }

        pub fn notify_one(&self) {
            rt::switch_point();
            let mut g = rt::slock();
            if g.aborting.is_some() {
                return;
            }
            let id = self.id();
            let waiters: Vec<usize> = g
                .threads
                .iter()
                .enumerate()
                .filter(|(_, r)| **r == Run::BlockedCondvar(id))
                .map(|(i, _)| i)
                .collect();
            if waiters.is_empty() {
                return; // a notify with no waiter is lost — real semantics
            }
            let pick = rt::choose(&mut g, waiters);
            if g.threads.get(pick) == Some(&Run::BlockedCondvar(id)) {
                g.threads[pick] = Run::Runnable;
            }
            drop(g);
            rt::sched().cv.notify_all();
        }

        pub fn notify_all(&self) {
            rt::switch_point();
            let mut g = rt::slock();
            if g.aborting.is_some() {
                return;
            }
            let id = self.id();
            for r in g.threads.iter_mut() {
                if *r == Run::BlockedCondvar(id) {
                    *r = Run::Runnable;
                }
            }
            drop(g);
            rt::sched().cv.notify_all();
        }
    }

    pub mod atomic {
        //! Model atomics: sequentially consistent, every op a switch point.

        pub use std::sync::atomic::Ordering;

        use crate::rt;
        use std::cell::Cell;

        macro_rules! atomic_int {
            ($name:ident, $ty:ty) => {
                /// Model atomic (SC; `Ordering` accepted and ignored).
                #[derive(Default, Debug)]
                pub struct $name {
                    v: Cell<$ty>,
                }

                // SAFETY: only the token-holding thread touches `v`, and
                // token handoff goes through the scheduler's std mutex,
                // which provides the happens-before edges.
                unsafe impl Send for $name {}
                unsafe impl Sync for $name {}

                impl $name {
                    pub fn new(v: $ty) -> Self {
                        Self { v: Cell::new(v) }
                    }

                    pub fn load(&self, _o: Ordering) -> $ty {
                        rt::switch_point();
                        self.v.get()
                    }

                    pub fn store(&self, val: $ty, _o: Ordering) {
                        rt::switch_point();
                        self.v.set(val);
                    }

                    pub fn swap(&self, val: $ty, _o: Ordering) -> $ty {
                        rt::switch_point();
                        self.v.replace(val)
                    }

                    pub fn fetch_add(&self, val: $ty, _o: Ordering) -> $ty {
                        rt::switch_point();
                        let old = self.v.get();
                        self.v.set(old.wrapping_add(val));
                        old
                    }

                    pub fn fetch_sub(&self, val: $ty, _o: Ordering) -> $ty {
                        rt::switch_point();
                        let old = self.v.get();
                        self.v.set(old.wrapping_sub(val));
                        old
                    }

                    pub fn fetch_max(&self, val: $ty, _o: Ordering) -> $ty {
                        rt::switch_point();
                        let old = self.v.get();
                        self.v.set(old.max(val));
                        old
                    }

                    pub fn fetch_min(&self, val: $ty, _o: Ordering) -> $ty {
                        rt::switch_point();
                        let old = self.v.get();
                        self.v.set(old.min(val));
                        old
                    }

                    pub fn compare_exchange(
                        &self,
                        current: $ty,
                        new: $ty,
                        _success: Ordering,
                        _failure: Ordering,
                    ) -> Result<$ty, $ty> {
                        rt::switch_point();
                        let old = self.v.get();
                        if old == current {
                            self.v.set(new);
                            Ok(old)
                        } else {
                            Err(old)
                        }
                    }

                    pub fn compare_exchange_weak(
                        &self,
                        current: $ty,
                        new: $ty,
                        success: Ordering,
                        failure: Ordering,
                    ) -> Result<$ty, $ty> {
                        // No spurious CAS failures in the model.
                        self.compare_exchange(current, new, success, failure)
                    }

                    pub fn into_inner(self) -> $ty {
                        self.v.into_inner()
                    }
                }
            };
        }

        atomic_int!(AtomicUsize, usize);
        atomic_int!(AtomicU64, u64);
        atomic_int!(AtomicU32, u32);
        atomic_int!(AtomicU8, u8);

        /// Model `AtomicBool` (SC; `Ordering` accepted and ignored).
        #[derive(Default, Debug)]
        pub struct AtomicBool {
            v: Cell<bool>,
        }

        // SAFETY: same argument as the integer atomics above.
        unsafe impl Send for AtomicBool {}
        unsafe impl Sync for AtomicBool {}

        impl AtomicBool {
            pub fn new(v: bool) -> Self {
                Self { v: Cell::new(v) }
            }

            pub fn load(&self, _o: Ordering) -> bool {
                rt::switch_point();
                self.v.get()
            }

            pub fn store(&self, val: bool, _o: Ordering) {
                rt::switch_point();
                self.v.set(val);
            }

            pub fn swap(&self, val: bool, _o: Ordering) -> bool {
                rt::switch_point();
                self.v.replace(val)
            }

            pub fn fetch_or(&self, val: bool, _o: Ordering) -> bool {
                rt::switch_point();
                let old = self.v.get();
                self.v.set(old | val);
                old
            }

            pub fn fetch_and(&self, val: bool, _o: Ordering) -> bool {
                rt::switch_point();
                let old = self.v.get();
                self.v.set(old & val);
                old
            }

            pub fn compare_exchange(
                &self,
                current: bool,
                new: bool,
                _success: Ordering,
                _failure: Ordering,
            ) -> Result<bool, bool> {
                rt::switch_point();
                let old = self.v.get();
                if old == current {
                    self.v.set(new);
                    Ok(old)
                } else {
                    Err(old)
                }
            }

            pub fn into_inner(self) -> bool {
                self.v.into_inner()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! Self-checks for the checker.  These run with plain `cargo test -p
    //! loom` (no special cfg: the checker itself is always compiled).

    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::{Arc, Condvar, Mutex};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// Unsynchronized read-modify-write across two threads must be caught
    /// as a lost update in at least one schedule.
    #[test]
    fn finds_lost_update() {
        let failed = catch_unwind(AssertUnwindSafe(|| {
            super::model(|| {
                let c = Arc::new(AtomicUsize::new(0));
                let c2 = Arc::clone(&c);
                let t = super::thread::spawn(move || {
                    let v = c2.load(Ordering::SeqCst);
                    c2.store(v + 1, Ordering::SeqCst);
                });
                let v = c.load(Ordering::SeqCst);
                c.store(v + 1, Ordering::SeqCst);
                t.join().unwrap();
                assert_eq!(c.load(Ordering::SeqCst), 2, "lost update");
            });
        }));
        assert!(failed.is_err(), "model must find the lost update");
    }

    /// The same counter protected by a mutex passes every schedule.
    #[test]
    fn mutex_counter_is_sound() {
        super::model(|| {
            let c = Arc::new(Mutex::new(0usize));
            let c2 = Arc::clone(&c);
            let t = super::thread::spawn(move || {
                *c2.lock().unwrap() += 1;
            });
            *c.lock().unwrap() += 1;
            t.join().unwrap();
            assert_eq!(*c.lock().unwrap(), 2);
        });
    }

    /// The classic lost wakeup: flag set + notify without holding the
    /// mutex the waiter checks under.  Must deadlock in some schedule.
    #[test]
    fn finds_lost_wakeup() {
        let failed = catch_unwind(AssertUnwindSafe(|| {
            super::model(|| {
                use super::sync::atomic::AtomicBool;
                let state = Arc::new((Mutex::new(()), Condvar::new(), AtomicBool::new(false)));
                let s2 = Arc::clone(&state);
                let t = super::thread::spawn(move || {
                    let (m, cv, flag) = &*s2;
                    let mut g = m.lock().unwrap();
                    while !flag.load(Ordering::SeqCst) {
                        g = cv.wait(g).unwrap();
                    }
                });
                let (_, cv, flag) = &*state;
                flag.store(true, Ordering::SeqCst); // BUG: not under the mutex
                cv.notify_all();
                t.join().unwrap();
            });
        }));
        assert!(failed.is_err(), "model must find the lost wakeup deadlock");
    }

    /// Fixed variant: the flag mutates under the mutex — passes.
    #[test]
    fn no_lost_wakeup_when_flag_under_lock() {
        super::model(|| {
            let state = Arc::new((Mutex::new(false), Condvar::new()));
            let s2 = Arc::clone(&state);
            let t = super::thread::spawn(move || {
                let (m, cv) = &*s2;
                let mut g = m.lock().unwrap();
                while !*g {
                    g = cv.wait(g).unwrap();
                }
            });
            let (m, cv) = &*state;
            *m.lock().unwrap() = true;
            cv.notify_all();
            t.join().unwrap();
        });
    }

    /// A predicate loop over `wait_timeout` terminates in every
    /// schedule: the model's timed wait always "times out", so a
    /// heartbeat thread parked on one can never deadlock the model,
    /// and the concurrent flag store is still observed.
    #[test]
    fn wait_timeout_never_blocks() {
        super::model(|| {
            let state = Arc::new((Mutex::new(false), Condvar::new()));
            let s2 = Arc::clone(&state);
            let t = super::thread::spawn(move || {
                let (m, cv) = &*s2;
                let mut g = m.lock().unwrap();
                while !*g {
                    let (g2, timed) =
                        cv.wait_timeout(g, std::time::Duration::from_millis(1)).unwrap();
                    g = g2;
                    assert!(timed.timed_out(), "the model's timed wait always times out");
                }
            });
            let (m, cv) = &*state;
            *m.lock().unwrap() = true;
            cv.notify_all();
            t.join().unwrap();
        });
    }

    /// Poisoning round-trips like std: child panics holding the lock,
    /// parent recovers via `PoisonError::into_inner`.
    #[test]
    fn poisoning_matches_std() {
        super::model(|| {
            let m = Arc::new(Mutex::new(7u32));
            let m2 = Arc::clone(&m);
            let t = super::thread::spawn(move || {
                let _g = m2.lock().unwrap();
                panic!("poison");
            });
            assert!(t.join().is_err());
            let g = match m.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            assert_eq!(*g, 7);
        });
    }
}
