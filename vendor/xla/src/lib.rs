//! Offline stub of the `xla` crate (PJRT C-API bindings).
//!
//! The real bindings need a PJRT plugin (`libpjrt_c_api_cpu.so` or a TPU
//! runtime) plus generated FFI — neither exists in the offline build
//! image.  This stub keeps the whole workspace compiling with the exact
//! call surface `runtime/executor.rs` uses; every runtime entry point
//! returns [`XlaError`] so callers that probe (`PjRtClient::cpu()`)
//! gate themselves off cleanly and fall back to the native engine.
//!
//! Swapping in the real crate is a one-line change in the workspace
//! manifest; no `palmad` source changes are required.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's (string-carrying) error.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: PJRT runtime not available (offline `xla` stub; link the real bindings \
         and a PJRT plugin to enable the AOT path)"
    )))
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub —
/// that is the probe callers use to detect the AOT path is off.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (text proto).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        unavailable(&format!("HloModuleProto::from_text_file({})", path.as_ref().display()))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (tensor value).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("PJRT runtime not available"), "{e}");
        assert!(Literal::vec1(&[1.0f32]).to_vec::<f32>().is_err());
    }
}
